//! Seeded PRNG + distributions (offline substitute for `rand`).
//!
//! Core generator is xoshiro256++ seeded through SplitMix64 — fast,
//! high-quality, and deterministic across platforms, which the experiment
//! harnesses rely on for reproducibility.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded construction (same seed -> same stream, on every platform).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Uniform usize in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless bounded sampling.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform u64 in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as usize) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with the given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate 1 (scale by lambda at the call site).
    pub fn exponential(&mut self) -> f64 {
        -self.f64().max(1e-300).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Partial Fisher–Yates: after the call, `xs[..k]` is a uniform random
    /// sample of the slice without replacement.
    pub fn partial_shuffle<T>(&mut self, xs: &mut [T], k: usize) {
        let n = xs.len();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.below(n - i);
            xs.swap(i, j);
        }
    }

    /// Pick an index according to non-negative weights (linear scan).
    /// Returns `weights.len() - 1` on total weight zero.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return weights.len().saturating_sub(1);
        }
        let mut t = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if t < w {
                return i;
            }
            t -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_uniform_ish() {
        let mut r = Rng::seed_from_u64(2);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 100_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn partial_shuffle_samples_distinct() {
        let mut r = Rng::seed_from_u64(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.partial_shuffle(&mut xs, 10);
        let mut head = xs[..10].to_vec();
        head.sort_unstable();
        head.dedup();
        assert_eq!(head.len(), 10);
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::seed_from_u64(6);
        let w = [0.0, 9.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[1] > 8_000);
    }

    #[test]
    fn exponential_mean_one() {
        let mut r = Rng::seed_from_u64(7);
        let m: f64 = (0..50_000).map(|_| r.exponential()).sum::<f64>() / 50_000.0;
        assert!((m - 1.0).abs() < 0.03, "mean {m}");
    }
}
