//! fvecs / ivecs IO — the interchange formats of the paper's datasets
//! (SIFT1B, Deep1B ship as .fvecs/.bvecs; ground truth as .ivecs).
//!
//! Format: each vector is `<d: i32 little-endian><d * element>`.

use super::Dataset;
use crate::error::{PyramidError, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Read an .fvecs file. `limit` caps the number of vectors (0 = all).
pub fn read_fvecs(path: &Path, limit: usize) -> Result<Dataset> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut buf = Vec::new();
    let mut d = 0usize;
    let mut n = 0usize;
    let mut head = [0u8; 4];
    loop {
        match r.read_exact(&mut head) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        }
        let dim = i32::from_le_bytes(head) as usize;
        if d == 0 {
            d = dim;
        } else if dim != d {
            return Err(PyramidError::Dataset(format!(
                "inconsistent fvecs dim: {dim} vs {d}"
            )));
        }
        let mut row = vec![0u8; dim * 4];
        r.read_exact(&mut row)?;
        buf.extend(row.chunks_exact(4).map(|c| {
            f32::from_le_bytes([c[0], c[1], c[2], c[3]])
        }));
        n += 1;
        if limit > 0 && n >= limit {
            break;
        }
    }
    if d == 0 {
        return Err(PyramidError::Dataset("empty fvecs file".into()));
    }
    Dataset::from_vec(buf, d)
}

/// Write a dataset as .fvecs.
pub fn write_fvecs(path: &Path, ds: &Dataset) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for row in ds.iter() {
        w.write_all(&(ds.dim() as i32).to_le_bytes())?;
        for v in row {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Read an .ivecs file (e.g. ground-truth neighbor ids).
pub fn read_ivecs(path: &Path, limit: usize) -> Result<Vec<Vec<i32>>> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut out = Vec::new();
    let mut head = [0u8; 4];
    loop {
        match r.read_exact(&mut head) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        }
        let dim = i32::from_le_bytes(head) as usize;
        let mut row = vec![0u8; dim * 4];
        r.read_exact(&mut row)?;
        out.push(
            row.chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        );
        if limit > 0 && out.len() >= limit {
            break;
        }
    }
    Ok(out)
}

/// Write an .ivecs file.
pub fn write_ivecs(path: &Path, rows: &[Vec<i32>]) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for row in rows {
        w.write_all(&(row.len() as i32).to_le_bytes())?;
        for v in row {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::SyntheticSpec;

    #[test]
    fn fvecs_roundtrip() {
        let dir = crate::util::tempdir::TempDir::new("io").unwrap();
        let p = dir.join("x.fvecs");
        let ds = SyntheticSpec::uniform(17, 5, 3).generate();
        write_fvecs(&p, &ds).unwrap();
        let back = read_fvecs(&p, 0).unwrap();
        assert_eq!(back.raw(), ds.raw());
        assert_eq!(back.dim(), 5);
        let limited = read_fvecs(&p, 4).unwrap();
        assert_eq!(limited.len(), 4);
    }

    #[test]
    fn ivecs_roundtrip() {
        let dir = crate::util::tempdir::TempDir::new("io").unwrap();
        let p = dir.join("gt.ivecs");
        let rows = vec![vec![1, 2, 3], vec![9, 8, 7]];
        write_ivecs(&p, &rows).unwrap();
        assert_eq!(read_ivecs(&p, 0).unwrap(), rows);
    }

    #[test]
    fn empty_file_is_error() {
        let dir = crate::util::tempdir::TempDir::new("io").unwrap();
        let p = dir.join("empty.fvecs");
        std::fs::File::create(&p).unwrap();
        assert!(read_fvecs(&p, 0).is_err());
    }
}
