//! Dataset substrate: flat row-major vector storage, synthetic generators
//! (substitutes for Deep500M / SIFT500M / Tiny10M — see DESIGN.md §3),
//! fvecs/bvecs/ivecs IO and sampling utilities.

mod io;
mod synthetic;

pub use io::{read_fvecs, read_ivecs, write_fvecs, write_ivecs};
pub use synthetic::{SyntheticKind, SyntheticSpec};

use crate::error::{PyramidError, Result};
use crate::metric::normalize_in_place;
use crate::types::VectorId;
use crate::util::aligned::AlignedF32;
use crate::util::rng::Rng;
use std::sync::Arc;

/// A dense, row-major f32 vector collection.
///
/// Storage is a single contiguous **32-byte-aligned** buffer
/// ([`AlignedF32`]) behind an `Arc` so sub-dataset views and worker
/// threads can share it without copies, and so AVX2/NEON row loads start
/// from an aligned base instead of wherever the allocator placed a plain
/// `Vec` (rows themselves land on 32-byte boundaries whenever `d` is a
/// multiple of 8, which every benchmarked configuration uses).
#[derive(Debug, Clone)]
pub struct Dataset {
    data: Arc<AlignedF32>,
    n: usize,
    d: usize,
}

impl Dataset {
    /// Wrap an existing buffer. `data.len()` must equal `n * d`. Copies
    /// once into the aligned store; producers that build large buffers
    /// should write into an [`AlignedF32`] directly and use
    /// [`Self::from_aligned`] to avoid the transient second allocation.
    pub fn from_vec(data: Vec<f32>, d: usize) -> Result<Self> {
        Self::from_aligned(AlignedF32::from_vec(data), d)
    }

    /// Wrap an already-aligned buffer without copying (the re-freeze
    /// compactor's path: its row gather writes straight into the buffer
    /// that becomes the new base's storage).
    pub fn from_aligned(data: AlignedF32, d: usize) -> Result<Self> {
        if d == 0 || data.len() % d != 0 {
            return Err(PyramidError::Dataset(format!(
                "buffer length {} is not a multiple of dim {d}",
                data.len()
            )));
        }
        let n = data.len() / d;
        Ok(Dataset { data: Arc::new(data), n, d })
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Row accessor.
    #[inline]
    pub fn get(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// The full flat buffer (row-major).
    pub fn raw(&self) -> &[f32] {
        &self.data
    }

    /// Iterate rows.
    pub fn iter(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.d)
    }

    /// Uniform random sample of `k` distinct rows (paper Alg 3 line 3).
    /// Returns a materialized dataset plus the chosen source ids.
    pub fn sample(&self, k: usize, seed: u64) -> (Dataset, Vec<VectorId>) {
        let k = k.min(self.n);
        let mut rng = Rng::seed_from_u64(seed);
        let mut ids: Vec<u32> = (0..self.n as u32).collect();
        rng.partial_shuffle(&mut ids, k);
        ids.truncate(k);
        let mut buf = Vec::with_capacity(k * self.d);
        for &i in &ids {
            buf.extend_from_slice(self.get(i as usize));
        }
        (Dataset::from_vec(buf, self.d).expect("sample buffer"), ids)
    }

    /// Copy of this dataset with every row normalized to unit norm
    /// (paper Alg 5 line 4; angular search §III-C).
    pub fn normalized(&self) -> Dataset {
        let mut buf = self.data.as_ref().clone();
        for row in buf.chunks_exact_mut(self.d) {
            normalize_in_place(row);
        }
        Dataset { data: Arc::new(buf), n: self.n, d: self.d }
    }

    /// Materialize a subset of rows as a new dataset (sub-dataset `X^i`).
    pub fn subset(&self, ids: &[VectorId]) -> Dataset {
        let mut buf = AlignedF32::with_capacity(ids.len() * self.d);
        for &i in ids {
            buf.extend_from_slice(self.get(i as usize));
        }
        Dataset { data: Arc::new(buf), n: ids.len(), d: self.d }
    }

    /// Euclidean norms of all rows.
    pub fn norms(&self) -> Vec<f32> {
        self.iter().map(crate::metric::norm).collect()
    }

    /// Append one row in place (the streaming delta-index write path).
    /// Copies the buffer first if any clone still shares it, so existing
    /// views are never mutated under a reader.
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.d, "push_row dim mismatch");
        let buf = std::sync::Arc::make_mut(&mut self.data);
        buf.extend_from_slice(row);
        self.n += 1;
    }
}

/// A sub-dataset: rows owned by one partition plus their global ids.
///
/// `local` row `j` corresponds to global vector `global_ids[j]`. MIPS
/// replication (Alg 5 lines 12-15) makes `global_ids` non-disjoint across
/// partitions.
#[derive(Debug, Clone)]
pub struct SubDataset {
    pub local: Dataset,
    pub global_ids: Vec<VectorId>,
}

impl SubDataset {
    pub fn new(parent: &Dataset, global_ids: Vec<VectorId>) -> Self {
        let local = parent.subset(&global_ids);
        SubDataset { local, global_ids }
    }

    pub fn len(&self) -> usize {
        self.global_ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.global_ids.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::from_vec((0..20).map(|i| i as f32).collect(), 4).unwrap()
    }

    #[test]
    fn from_vec_checks_shape() {
        assert!(Dataset::from_vec(vec![0.0; 7], 4).is_err());
        assert!(Dataset::from_vec(vec![0.0; 8], 0).is_err());
        let ds = toy();
        assert_eq!((ds.len(), ds.dim()), (5, 4));
        assert_eq!(ds.get(1), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn sample_is_distinct_and_seeded() {
        let ds = toy();
        let (s1, ids1) = ds.sample(3, 42);
        let (_, ids2) = ds.sample(3, 42);
        assert_eq!(ids1, ids2);
        assert_eq!(s1.len(), 3);
        let set: std::collections::HashSet<_> = ids1.iter().collect();
        assert_eq!(set.len(), 3);
        // Sampled rows match their source rows.
        for (j, &i) in ids1.iter().enumerate() {
            assert_eq!(s1.get(j), ds.get(i as usize));
        }
    }

    #[test]
    fn sample_larger_than_n_clamps() {
        let ds = toy();
        let (s, ids) = ds.sample(100, 1);
        assert_eq!(s.len(), 5);
        assert_eq!(ids.len(), 5);
    }

    #[test]
    fn normalized_rows_unit_norm() {
        let ds = toy().normalized();
        for row in ds.iter().skip(1) {
            assert!((crate::metric::norm(row) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn push_row_grows_without_touching_clones() {
        let mut ds = toy();
        let view = ds.clone();
        ds.push_row(&[100.0, 101.0, 102.0, 103.0]);
        assert_eq!(ds.len(), 6);
        assert_eq!(ds.get(5), &[100.0, 101.0, 102.0, 103.0]);
        // The pre-push clone still sees the old buffer.
        assert_eq!(view.len(), 5);
        assert_eq!(view.get(4), ds.get(4));
    }

    /// Satellite acceptance (SQ8 PR): row storage is allocated 32-byte
    /// aligned so vector loads on the f32 plane never straddle cache
    /// lines for lane-multiple dims — including after in-place growth
    /// (`push_row` reallocations) and for derived datasets.
    #[test]
    fn row_storage_is_32_byte_aligned() {
        let mut ds = Dataset::from_vec((0..32 * 9).map(|i| i as f32).collect(), 8).unwrap();
        assert_eq!(ds.raw().as_ptr() as usize % 32, 0);
        for _ in 0..100 {
            ds.push_row(&[1.0; 8]);
        }
        assert_eq!(ds.raw().as_ptr() as usize % 32, 0, "push_row realloc lost alignment");
        let sub = ds.subset(&[0, 5, 7]);
        assert_eq!(sub.raw().as_ptr() as usize % 32, 0, "subset lost alignment");
        let norm = ds.normalized();
        assert_eq!(norm.raw().as_ptr() as usize % 32, 0, "normalized lost alignment");
        // d = 8 floats = 32 bytes: every row starts on an aligned boundary.
        for i in 0..ds.len() {
            assert_eq!(ds.get(i).as_ptr() as usize % 32, 0, "row {i} misaligned");
        }
    }

    #[test]
    fn subset_preserves_rows() {
        let ds = toy();
        let sub = SubDataset::new(&ds, vec![4, 0]);
        assert_eq!(sub.local.get(0), ds.get(4));
        assert_eq!(sub.local.get(1), ds.get(0));
        assert_eq!(sub.global_ids, vec![4, 0]);
    }
}
