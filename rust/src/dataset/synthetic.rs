//! Synthetic dataset generators — substitutes for the paper's Deep500M,
//! SIFT500M and Tiny10M (DESIGN.md §3).
//!
//! The properties the experiments actually depend on are (a) *cluster
//! structure* — Pyramid's partitioning only pays off when similar items can
//! be grouped, which holds for real descriptor datasets; and (b) the *norm
//! distribution* — the MIPS experiments (Fig 3, Fig 10) need a wide norm
//! spread. `DeepLike`/`SiftLike` are Gaussian mixtures with near-constant
//! row norms (like real deep/SIFT descriptors after whitening); `TinyLike`
//! scales mixture samples by log-normal norms to reproduce the Fig-3 bias.

use super::Dataset;
use crate::util::rng::Rng;

/// Which real dataset the generator imitates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyntheticKind {
    /// Deep1B-style: CNN descriptors, strongly clustered, ~unit norms.
    DeepLike,
    /// SIFT-style: local feature descriptors, moderately clustered,
    /// near-constant norms.
    SiftLike,
    /// Tiny/GIST-style: wide log-normal norm spread (for MIPS).
    TinyLike,
    /// Uniform noise — worst case for partitioning (ablation baseline).
    Uniform,
}

impl std::str::FromStr for SyntheticKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "deep" | "deep_like" | "deeplike" => Ok(SyntheticKind::DeepLike),
            "sift" | "sift_like" | "siftlike" => Ok(SyntheticKind::SiftLike),
            "tiny" | "tiny_like" | "tinylike" => Ok(SyntheticKind::TinyLike),
            "uniform" => Ok(SyntheticKind::Uniform),
            other => Err(format!("unknown synthetic kind: {other}")),
        }
    }
}

impl SyntheticKind {
    pub fn key(&self) -> &'static str {
        match self {
            SyntheticKind::DeepLike => "deep_like",
            SyntheticKind::SiftLike => "sift_like",
            SyntheticKind::TinyLike => "tiny_like",
            SyntheticKind::Uniform => "uniform",
        }
    }
}

/// Generator specification.
#[derive(Debug, Clone, Copy)]
pub struct SyntheticSpec {
    pub kind: SyntheticKind,
    pub n: usize,
    pub d: usize,
    /// Number of mixture components (cluster count).
    pub clusters: usize,
    /// Cluster center spread vs within-cluster noise; higher = more
    /// separable clusters.
    pub separation: f32,
    pub seed: u64,
}

impl SyntheticSpec {
    pub fn deep_like(n: usize, d: usize, seed: u64) -> Self {
        SyntheticSpec { kind: SyntheticKind::DeepLike, n, d, clusters: 256, separation: 3.0, seed }
    }

    pub fn sift_like(n: usize, d: usize, seed: u64) -> Self {
        SyntheticSpec { kind: SyntheticKind::SiftLike, n, d, clusters: 128, separation: 2.0, seed }
    }

    pub fn tiny_like(n: usize, d: usize, seed: u64) -> Self {
        SyntheticSpec { kind: SyntheticKind::TinyLike, n, d, clusters: 64, separation: 2.0, seed }
    }

    pub fn uniform(n: usize, d: usize, seed: u64) -> Self {
        SyntheticSpec { kind: SyntheticKind::Uniform, n, d, clusters: 1, separation: 0.0, seed }
    }

    /// Generate the dataset (deterministic in `seed`).
    pub fn generate(&self) -> Dataset {
        let mut rng = Rng::seed_from_u64(self.seed);
        let mut buf = vec![0f32; self.n * self.d];
        match self.kind {
            SyntheticKind::Uniform => {
                for v in buf.iter_mut() {
                    *v = rng.f32_range(-1.0, 1.0);
                }
            }
            _ => {
                let k = self.clusters.max(1);
                // Cluster centers: N(0, separation^2) per coordinate.
                let mut centers = vec![0f32; k * self.d];
                for c in centers.iter_mut() {
                    *c = rng.normal() as f32 * self.separation;
                }
                // Zipf-ish skewed cluster popularity for DeepLike (real CNN
                // descriptor clusters are uneven); uniform for SiftLike.
                let weights: Vec<f64> = (0..k)
                    .map(|i| match self.kind {
                        SyntheticKind::DeepLike => 1.0 / ((i + 1) as f64).sqrt(),
                        _ => 1.0,
                    })
                    .collect();
                let lognormal = matches!(self.kind, SyntheticKind::TinyLike);
                for row in buf.chunks_exact_mut(self.d) {
                    let ci = rng.weighted(&weights);
                    let center = &centers[ci * self.d..(ci + 1) * self.d];
                    for (v, c) in row.iter_mut().zip(center) {
                        *v = c + rng.normal() as f32;
                    }
                    if lognormal {
                        // Rescale the row to a log-normal target norm.
                        // sigma=0.5 gives a ~5x spread at the tails,
                        // matching Tiny10M's "wide spread" description.
                        let cur = crate::metric::norm(row);
                        if cur > 1e-9 {
                            let target = rng.lognormal(0.0, 0.5) as f32 * (self.d as f32).sqrt();
                            let s = target / cur;
                            for v in row.iter_mut() {
                                *v *= s;
                            }
                        }
                    }
                }
            }
        }
        Dataset::from_vec(buf, self.d).expect("synthetic buffer")
    }

    /// Generate `q` held-out queries from the same distribution
    /// (fresh seed offset so queries are not dataset rows).
    pub fn queries(&self, q: usize) -> Dataset {
        let mut spec = *self;
        spec.n = q;
        spec.seed = self.seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        spec.generate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::norm;

    #[test]
    fn deterministic_in_seed() {
        let a = SyntheticSpec::deep_like(100, 16, 5).generate();
        let b = SyntheticSpec::deep_like(100, 16, 5).generate();
        assert_eq!(a.raw(), b.raw());
        let c = SyntheticSpec::deep_like(100, 16, 6).generate();
        assert_ne!(a.raw(), c.raw());
    }

    #[test]
    fn shapes() {
        let ds = SyntheticSpec::sift_like(50, 32, 1).generate();
        assert_eq!((ds.len(), ds.dim()), (50, 32));
        let q = SyntheticSpec::sift_like(50, 32, 1).queries(7);
        assert_eq!(q.len(), 7);
    }

    #[test]
    fn tiny_like_has_wide_norm_spread() {
        let ds = SyntheticSpec::tiny_like(2000, 24, 3).generate();
        let mut norms: Vec<f32> = ds.iter().map(norm).collect();
        norms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p5 = norms[100];
        let p95 = norms[1900];
        assert!(p95 / p5 > 2.0, "norm spread too narrow: {p5} .. {p95}");
    }

    #[test]
    fn deep_like_norms_are_concentrated() {
        let ds = SyntheticSpec::deep_like(2000, 64, 3).generate();
        let mut norms: Vec<f32> = ds.iter().map(norm).collect();
        norms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Spread should be far narrower than TinyLike's.
        assert!(norms[1900] / norms[100] < 2.0);
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in [
            SyntheticKind::DeepLike,
            SyntheticKind::SiftLike,
            SyntheticKind::TinyLike,
            SyntheticKind::Uniform,
        ] {
            assert_eq!(k.key().parse::<SyntheticKind>().unwrap(), k);
        }
        assert!("bogus".parse::<SyntheticKind>().is_err());
    }

    #[test]
    fn clustered_data_is_actually_clustered() {
        // Mean nearest-neighbor distance relative to mean pairwise distance
        // must be much smaller for the clustered generator than uniform.
        let ratio = |ds: &Dataset| {
            let mut nn = 0.0f64;
            let mut pair = 0.0f64;
            let mut pairs = 0usize;
            for i in 0..40 {
                let mut best = f32::MAX;
                for j in 0..ds.len() {
                    if i == j {
                        continue;
                    }
                    let d = crate::metric::l2_sq_unrolled(ds.get(i), ds.get(j));
                    best = best.min(d);
                    if j < 40 {
                        pair += d as f64;
                        pairs += 1;
                    }
                }
                nn += best as f64;
            }
            (nn / 40.0) / (pair / pairs as f64)
        };
        let clustered = SyntheticSpec::deep_like(800, 16, 9).generate();
        let uniform = SyntheticSpec::uniform(800, 16, 9).generate();
        assert!(
            ratio(&clustered) < ratio(&uniform),
            "clustered {} vs uniform {}",
            ratio(&clustered),
            ratio(&uniform)
        );
    }
}
