//! Public high-level API — the paper's Listings 1–3.
//!
//! These wrap the internal nodes with the file-based contract a real
//! deployment uses: [`GraphConstructor`] builds and writes the index to a
//! path; [`Coordinator`] loads the meta-HNSW from that path and serves
//! `execute`/`execute_async`; [`Executor`] loads one sub-HNSW and serves
//! its topic until stopped. "Brokers" is the in-process [`Broker`] handle
//! (the Kafka substitute), shared by all parties.

use crate::broker::Broker;
use crate::cluster::SimCluster;
use crate::config::{ClusterTopology, IndexConfig, QueryParams};
use crate::coordinator::{topic_for, CoordinatorConfig, CoordinatorNode, QueryRequest};
use crate::error::Result;
use crate::executor::{ExecutorHandle, ExecutorSpec, HostControl, IngestWiring};
use crate::ingest::{update_topic_for, IngestConfig, IngestGateway, LiveIndex};
use crate::meta::PyramidIndex;
use crate::metric::Metric;
use crate::registry::Registry;
use crate::types::{Neighbor, PartitionId, QueryResult, UpdateRequest, VectorId};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Listing 3: index construction.
///
/// ```ignore
/// let gc = GraphConstructor::new(data_path, metric, para);
/// gc.construct(out_dir)?;
/// gc.refresh()?;   // re-read dataset, rebuild, re-notify
/// ```
pub struct GraphConstructor {
    dataset: crate::config::DatasetConfig,
    metric: Metric,
    para: IndexConfig,
    out_dir: std::sync::Mutex<Option<PathBuf>>,
}

impl GraphConstructor {
    pub fn new(dataset: crate::config::DatasetConfig, metric: Metric, para: IndexConfig) -> Self {
        GraphConstructor { dataset, metric, para, out_dir: std::sync::Mutex::new(None) }
    }

    /// Build the meta-HNSW and sub-HNSWs and write them under `out_dir`.
    /// Returns the in-memory index as well (useful for tests/harnesses).
    pub fn construct(&self, out_dir: &Path) -> Result<PyramidIndex> {
        let data = self.dataset.load()?;
        let idx = PyramidIndex::build(&data, self.metric, &self.para)?;
        idx.save(out_dir)?;
        *self.out_dir.lock().unwrap() = Some(out_dir.to_path_buf());
        Ok(idx)
    }

    /// Re-read the dataset and rebuild in place (paper: "reads the dataset
    /// again, reconstructs the graphs and notifies the coordinators and
    /// executors"). Notification is by file mtime — loaders re-open the
    /// path.
    pub fn refresh(&self) -> Result<PyramidIndex> {
        let dir = self
            .out_dir
            .lock()
            .unwrap()
            .clone()
            .ok_or_else(|| crate::error::PyramidError::Index("construct() before refresh()".into()))?;
        self.construct(&dir)
    }
}

/// Listing 1: the coordinator class.
pub struct Coordinator {
    node: Arc<CoordinatorNode>,
}

impl Coordinator {
    /// `Coordinator(brokers, graph_path, name, metric)` — loads the
    /// meta-HNSW replica from `graph_path` and binds to the brokers.
    pub fn new(brokers: Broker<QueryRequest>, graph_path: &Path, id: u64) -> Result<Coordinator> {
        let router = PyramidIndex::load_router(graph_path)?;
        Ok(Coordinator { node: CoordinatorNode::new(id, router, brokers, CoordinatorConfig::default()) })
    }

    /// Synchronous query (Listing 1 `execute`).
    pub fn execute(&self, query: &[f32], para: &QueryParams) -> Result<Vec<Neighbor>> {
        self.node.execute(query, para)
    }

    /// Batched synchronous query — Listing 1's `execute`, batch-native:
    /// one meta-HNSW routing pass, one broker fan-out and one gather for
    /// the whole block. Per-query results are identical to sequential
    /// [`Self::execute`] calls.
    pub fn execute_batch(&self, queries: &[&[f32]], para: &QueryParams) -> Result<Vec<Vec<Neighbor>>> {
        self.node.execute_batch(queries, para)
    }

    /// [`Self::execute`] with the coverage report (paper §IV-B): a
    /// partition with zero live replicas degrades the result
    /// ([`QueryResult::coverage`] < 1) instead of erroring.
    pub fn execute_detailed(&self, query: &[f32], para: &QueryParams) -> Result<QueryResult> {
        self.node.execute_detailed(query, para)
    }

    /// Batched [`Self::execute_detailed`].
    pub fn execute_batch_detailed(
        &self,
        queries: &[&[f32]],
        para: &QueryParams,
    ) -> Result<Vec<QueryResult>> {
        self.node.execute_batch_detailed(queries, para)
    }

    /// Asynchronous query with callback (Listing 1 `execute_async`).
    pub fn execute_async<F>(&self, query: Vec<f32>, para: QueryParams, callback: F) -> Result<()>
    where
        F: FnOnce(Result<Vec<Neighbor>>) + Send + 'static,
    {
        self.node.clone().execute_async(query, para, callback)
    }

    /// Attach a telemetry plane ([`crate::obs::Obs`]): queries mint
    /// traces and the coordinator's counters land in the plane's
    /// [`crate::obs::MetricsRegistry`]. Standalone deployments share one
    /// bundle across their coordinators the same way [`SimCluster`] does.
    pub fn enable_obs(&self, obs: Arc<crate::obs::Obs>) {
        self.node.enable_obs(obs);
    }

    /// Attach the streaming-ingest write gateway (see
    /// [`crate::ingest`]); afterwards [`Self::insert`]/[`Self::delete`]
    /// accept writes. Coordinators of one deployment must share the
    /// gateway (clone it) so assigned ids never collide.
    pub fn enable_ingest(&self, gateway: IngestGateway) {
        self.node.enable_ingest(gateway);
    }

    /// Insert one vector into the live index; returns its global id.
    /// The vector is searchable — by [`Self::execute`] — within one
    /// executor poll cycle, with no rebuild ([`GraphConstructor`] stays
    /// out of the loop entirely).
    pub fn insert(&self, vector: &[f32]) -> Result<VectorId> {
        self.node.insert(vector)
    }

    /// Batched [`Self::insert`] (one routing pass for the block).
    pub fn insert_batch(&self, vectors: &[&[f32]]) -> Result<Vec<VectorId>> {
        self.node.insert_batch(vectors)
    }

    /// Delete a vector by global id (tombstoned on every partition).
    pub fn delete(&self, id: VectorId) -> Result<()> {
        self.node.delete(id)
    }

    /// Batched [`Self::delete`].
    pub fn delete_batch(&self, ids: &[VectorId]) -> Result<()> {
        self.node.delete_batch(ids)
    }

    pub fn node(&self) -> &Arc<CoordinatorNode> {
        &self.node
    }
}

/// Listing 2: the executor class. `start` runs until the handle is
/// stopped; a standalone binary (`pyramid serve-executor`) wraps this with
/// zero custom logic, as the paper prescribes.
pub struct Executor {
    brokers: Broker<QueryRequest>,
    registry: Registry,
    graph_path: PathBuf,
    partition: PartitionId,
    id: u64,
}

impl Executor {
    pub fn new(
        brokers: Broker<QueryRequest>,
        registry: Registry,
        graph_path: &Path,
        partition: PartitionId,
        id: u64,
    ) -> Executor {
        Executor { brokers, registry, graph_path: graph_path.to_path_buf(), partition, id }
    }

    /// Load the sub-HNSW and start serving. Returns the running handle.
    pub fn start(&self) -> Result<ExecutorHandle> {
        let (sub, ids) = PyramidIndex::load_partition(&self.graph_path, self.partition as usize)?;
        self.brokers.create_topic(&topic_for(self.partition));
        Ok(crate::executor::spawn(
            ExecutorSpec {
                id: self.id,
                partition: self.partition,
                sub,
                ids,
                host: HostControl::new(usize::MAX),
                net_latency: std::time::Duration::ZERO,
                batch: crate::executor::DEFAULT_BATCH,
                ingest: None,
                obs: None,
            },
            self.brokers.clone(),
            self.registry.clone(),
        ))
    }

    /// [`Self::start`], writable: the loaded sub-HNSW becomes the frozen
    /// base of a [`LiveIndex`], and the executor tails the partition's
    /// update topic on `update_brokers` — inserts/deletes published by
    /// an ingest-enabled [`Coordinator`] are absorbed live, and a
    /// replacement instance started the same way replays the retained
    /// log from scratch (paper §IV-B recovery, for writes). Returns the
    /// handle plus the live index for observability (delta size,
    /// re-freeze count).
    pub fn start_ingesting(
        &self,
        update_brokers: &Broker<UpdateRequest>,
        cfg: IngestConfig,
    ) -> Result<(ExecutorHandle, Arc<LiveIndex>)> {
        let (sub, ids) = PyramidIndex::load_partition(&self.graph_path, self.partition as usize)?;
        self.brokers.create_topic(&topic_for(self.partition));
        update_brokers.create_topic(&update_topic_for(self.partition));
        let live = Arc::new(LiveIndex::new(sub, ids.clone(), cfg));
        let handle = crate::executor::spawn(
            ExecutorSpec {
                id: self.id,
                partition: self.partition,
                sub: live.clone(),
                ids,
                host: HostControl::new(usize::MAX),
                net_latency: std::time::Duration::ZERO,
                batch: crate::executor::DEFAULT_BATCH,
                ingest: Some(IngestWiring {
                    broker: update_brokers.clone(),
                    live: live.clone(),
                    freeze: None,
                }),
                obs: None,
            },
            self.brokers.clone(),
            self.registry.clone(),
        );
        Ok((handle, live))
    }
}

/// One-call convenience: build (or load) an index and start a simulated
/// cluster over it — what the examples and figure harnesses use.
pub fn serve(index: &PyramidIndex, topo: ClusterTopology) -> Result<SimCluster> {
    SimCluster::start(index, topo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::BrokerConfig;
    use crate::config::DatasetConfig;
    use crate::dataset::SyntheticKind;
    use crate::registry::RegistryConfig;
    use crate::util::tempdir::TempDir;

    #[test]
    fn listings_1_2_3_end_to_end() {
        // Listing 3: construct.
        let gc = GraphConstructor::new(
            DatasetConfig::synthetic(SyntheticKind::DeepLike, 2_000, 16, 5),
            Metric::L2,
            IndexConfig { sample: 600, meta_size: 16, partitions: 2, ..Default::default() },
        );
        let dir = TempDir::new("api").unwrap();
        let idx = gc.construct(dir.path()).unwrap();
        assert_eq!(idx.partitions(), 2);

        // Shared "brokers" + registry.
        let brokers: Broker<QueryRequest> = Broker::new(BrokerConfig {
            rebalance_pause: std::time::Duration::from_millis(1),
            ..BrokerConfig::default()
        });
        for p in 0..2u16 {
            brokers.create_topic(&topic_for(p));
        }
        let registry = Registry::new(RegistryConfig::default());

        // Listing 2: two executors, one per sub-HNSW.
        let e0 = Executor::new(brokers.clone(), registry.clone(), dir.path(), 0, 100).start().unwrap();
        let e1 = Executor::new(brokers.clone(), registry.clone(), dir.path(), 1, 101).start().unwrap();

        // Listing 1: a coordinator serving queries.
        let coord = Coordinator::new(brokers, dir.path(), 0).unwrap();
        let data = DatasetConfig::synthetic(SyntheticKind::DeepLike, 2_000, 16, 5).load().unwrap();
        let para = QueryParams { k: 5, branch: 2, ef: 80, meta_ef: 80 };
        let res = coord.execute(data.get(17), &para).unwrap();
        assert_eq!(res.len(), 5);
        assert_eq!(res[0].id, 17, "item should be its own nearest neighbor");

        // Batch entry point (batch-native Listing 1): identical per-query
        // top-k to sequential execute.
        let batch_q: Vec<&[f32]> = (10usize..14).map(|i| data.get(i)).collect();
        let batched = coord.execute_batch(&batch_q, &para).unwrap();
        assert_eq!(batched.len(), 4);
        for (j, q) in batch_q.iter().enumerate() {
            let seq = coord.execute(q, &para).unwrap();
            assert_eq!(
                batched[j].iter().map(|n| n.id).collect::<Vec<_>>(),
                seq.iter().map(|n| n.id).collect::<Vec<_>>(),
                "batched query {j} diverges from sequential execute"
            );
            assert_eq!(batched[j][0].id, (10 + j) as u32);
        }

        // execute_async delivers through the callback.
        let (tx, rx) = std::sync::mpsc::channel();
        coord
            .execute_async(data.get(3).to_vec(), para, move |r| {
                let _ = tx.send(r.map(|v| v[0].id));
            })
            .unwrap();
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap().unwrap(), 3);

        // Listing 3: refresh rebuilds in place.
        let idx2 = gc.refresh().unwrap();
        assert_eq!(idx2.partitions(), 2);

        e0.stop();
        e1.stop();
        coord.node().shutdown();
    }
}
