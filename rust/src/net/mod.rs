//! Network cost model — the transport plane (DESIGN.md; ROADMAP #5).
//!
//! Everything above the [`crate::broker::Broker`] seam is transport-
//! agnostic: a [`NetModel`] prices every delivery (per-link latency +
//! bandwidth cost between endpoints, with serialized-size accounting via
//! [`WireSize`]) and the broker folds that price into a message's
//! visibility instant — the same mechanism seeded
//! [`crate::chaos::FaultPlan`] delays use, so network cost and injected
//! faults compose deterministically at one seam.
//!
//! Three models ship:
//!
//! * [`IdealNet`] — free, instantaneous delivery: the pre-transport-plane
//!   behavior, bit-identical by construction (the broker short-circuits
//!   all accounting when no model is installed);
//! * [`UniformNet`] — flat latency plus serialization at a flat bandwidth
//!   for every endpoint pair;
//! * [`FatTreeNet`] — hosts → racks → spine: rack-local transfers pay two
//!   hops at full bandwidth, cross-rack transfers pay four hops, an
//!   oversubscription factor, and FIFO queuing on the shared per-rack-pair
//!   spine link, so concurrent cross-rack fanout self-congests the way a
//!   real Clos fabric does.
//!
//! Endpoint ids reuse the chaos id space ([`crate::chaos`]): ids below
//! 2^32 are hosts (`host_endpoint`), everything else — coordinators, the
//! broker itself, `EP_NONE` — attaches at rack 0 (the client/gateway
//! rack). Host→rack placement is `host / hosts_per_rack` from
//! [`crate::config::ClusterTopology::hosts_per_rack`].
//!
//! [`SimClock`] is the virtual clock behind all broker timing: real time
//! plus a monotonically-growing skew, so tests advance leases, sessions
//! and delivery delays deterministically instead of sleeping
//! ([`crate::broker::Broker::advance_clock`]).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Endpoint ids at or above this are not hosts (coordinators, broker,
/// `EP_NONE`); they attach at rack 0. Mirrors
/// `crate::chaos::coordinator_endpoint`'s `1 << 32` tag.
const HOST_EP_LIMIT: u64 = 1 << 32;

/// A virtual clock: real monotonic time plus an atomic skew that only
/// ever grows. With zero skew (the default, and the only state production
/// code ever sees) `now()` is exactly `Instant::now()` — advancing is a
/// test/simulation hook that jumps leases, session timeouts and delivery
/// delays forward without sleeping.
#[derive(Clone, Default)]
pub struct SimClock {
    skew_ns: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock::default()
    }

    /// Current virtual time. Monotone: skew only grows.
    pub fn now(&self) -> Instant {
        Instant::now() + Duration::from_nanos(self.skew_ns.load(Ordering::Relaxed))
    }

    /// Jump the clock forward by `d` (affects every clone).
    pub fn advance(&self, d: Duration) {
        self.skew_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Total skew applied so far.
    pub fn skew(&self) -> Duration {
        Duration::from_nanos(self.skew_ns.load(Ordering::Relaxed))
    }
}

/// Serialized size of a message on the wire, in bytes. The broker charges
/// the installed [`NetModel`] per delivery using this — sub-queries,
/// partials and log records all price by their real payload, not a flat
/// per-message constant.
pub trait WireSize {
    fn wire_bytes(&self) -> usize;
}

impl WireSize for u32 {
    fn wire_bytes(&self) -> usize {
        4
    }
}

impl WireSize for u64 {
    fn wire_bytes(&self) -> usize {
        8
    }
}

impl WireSize for String {
    fn wire_bytes(&self) -> usize {
        8 + self.len()
    }
}

impl WireSize for () {
    fn wire_bytes(&self) -> usize {
        0
    }
}

/// A pluggable network cost model: the one-way delivery cost of `bytes`
/// from endpoint `src` to endpoint `dst` at virtual time `now`. Stateful
/// models (per-link queuing) update their internal link occupancy as a
/// side effect, so concurrent transfers over a shared link serialize.
pub trait NetModel: Send + Sync {
    fn name(&self) -> &'static str;
    fn delay(&self, src: u64, dst: u64, bytes: usize, now: Instant) -> Duration;
}

/// Serialization time of `bytes` at `gbps` gigabit/s.
fn xmit(bytes: usize, gbps: u64) -> Duration {
    Duration::from_nanos(bytes as u64 * 8 / gbps.max(1))
}

/// Free, instantaneous delivery — the null model. The broker treats "no
/// model installed" identically (and skips the accounting entirely), so
/// `Ideal` is bit-identical to the pre-transport-plane behavior.
#[derive(Debug, Default, Clone, Copy)]
pub struct IdealNet;

impl NetModel for IdealNet {
    fn name(&self) -> &'static str {
        "ideal"
    }

    fn delay(&self, _src: u64, _dst: u64, _bytes: usize, _now: Instant) -> Duration {
        Duration::ZERO
    }
}

/// Flat latency + flat bandwidth between every distinct endpoint pair.
#[derive(Debug, Clone, Copy)]
pub struct UniformNet {
    pub latency: Duration,
    pub gbps: u64,
}

impl NetModel for UniformNet {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn delay(&self, src: u64, dst: u64, bytes: usize, _now: Instant) -> Duration {
        if src == dst {
            return Duration::ZERO;
        }
        self.latency + xmit(bytes, self.gbps)
    }
}

/// Two-tier Clos fabric: hosts attach to top-of-rack switches, racks
/// attach to a spine. Rack-local transfers pay `2 * hop` propagation plus
/// serialization at full bandwidth; cross-rack transfers pay `4 * hop`,
/// serialization inflated by the `oversub` factor, and FIFO queuing on
/// the shared spine link for that rack pair — concurrent cross-rack
/// fanout congests, rack-local traffic never does.
pub struct FatTreeNet {
    /// Hosts per rack (normalized ≥ 1; `usize::MAX` = everything rack 0).
    hosts_per_rack: usize,
    /// One-way propagation per switch hop.
    hop: Duration,
    pub gbps: u64,
    /// Cross-rack bandwidth divisor (spine oversubscription).
    oversub: u32,
    /// Per spine link (unordered rack pair): when it next frees up.
    busy: Mutex<HashMap<(usize, usize), Instant>>,
}

impl FatTreeNet {
    /// `hosts_per_rack == 0` means every host shares rack 0.
    pub fn new(hosts_per_rack: usize, hop: Duration, gbps: u64, oversub: u32) -> Self {
        FatTreeNet {
            hosts_per_rack: if hosts_per_rack == 0 { usize::MAX } else { hosts_per_rack },
            hop,
            gbps,
            oversub: oversub.max(1),
            busy: Mutex::new(HashMap::new()),
        }
    }

    /// Rack of an endpoint: hosts map by `host / hosts_per_rack`;
    /// non-host endpoints (coordinators, broker, `EP_NONE`) attach at
    /// rack 0, the client/gateway rack.
    pub fn rack_of(&self, ep: u64) -> usize {
        if ep < HOST_EP_LIMIT {
            (ep as usize) / self.hosts_per_rack
        } else {
            0
        }
    }
}

impl NetModel for FatTreeNet {
    fn name(&self) -> &'static str {
        "fat_tree"
    }

    fn delay(&self, src: u64, dst: u64, bytes: usize, now: Instant) -> Duration {
        if src == dst {
            return Duration::ZERO;
        }
        let (ra, rb) = (self.rack_of(src), self.rack_of(dst));
        let wire = xmit(bytes, self.gbps);
        if ra == rb {
            return self.hop * 2 + wire;
        }
        // Cross-rack: queue on the shared spine link for this rack pair.
        let ser = wire * self.oversub;
        let key = (ra.min(rb), ra.max(rb));
        let mut busy = self.busy.lock().unwrap();
        let start = busy.get(&key).copied().unwrap_or(now).max(now);
        let done = start + ser;
        busy.insert(key, done);
        self.hop * 4 + (done - now)
    }
}

/// Which network model a cluster runs under. `Copy` on purpose:
/// [`crate::config::ClusterTopology`] is `Copy` and carries one of these,
/// so parameterized variants hold plain numbers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NetSpec {
    /// Resolve from the `PYRAMID_NET` env var at cluster start (`ideal`,
    /// `uniform`, `fat_tree`); [`NetSpec::Ideal`] when unset. This is the
    /// CI matrix toggle — tests that pin exact behavior use an explicit
    /// variant instead.
    #[default]
    Auto,
    /// Free delivery (the default resolution; bit-identical to the
    /// pre-transport-plane broker).
    Ideal,
    /// Flat `latency_us` + serialization at `gbps` for every pair.
    Uniform { latency_us: u64, gbps: u64 },
    /// Hosts→racks→spine with per-hop latency `hop_us`, edge bandwidth
    /// `gbps`, and spine oversubscription `oversub`.
    FatTree { hop_us: u64, gbps: u64, oversub: u32 },
}

impl NetSpec {
    /// Env-resolution defaults for `PYRAMID_NET=uniform`.
    pub const ENV_UNIFORM: NetSpec = NetSpec::Uniform { latency_us: 200, gbps: 10 };
    /// Env-resolution defaults for `PYRAMID_NET=fat_tree`.
    pub const ENV_FAT_TREE: NetSpec = NetSpec::FatTree { hop_us: 100, gbps: 10, oversub: 4 };

    /// Collapse [`NetSpec::Auto`] through the `PYRAMID_NET` env var;
    /// explicit variants pass through untouched (a pinned test beats the
    /// CI matrix toggle).
    pub fn resolve(self) -> NetSpec {
        match self {
            NetSpec::Auto => match std::env::var("PYRAMID_NET").ok().as_deref() {
                Some("uniform") => NetSpec::ENV_UNIFORM,
                Some("fat_tree") | Some("fattree") => NetSpec::ENV_FAT_TREE,
                _ => NetSpec::Ideal,
            },
            other => other,
        }
    }

    /// Build the model for a cluster whose racks hold `hosts_per_rack`
    /// hosts. `None` means ideal: the broker skips accounting entirely.
    pub fn build(self, hosts_per_rack: usize) -> Option<Arc<dyn NetModel>> {
        match self.resolve() {
            NetSpec::Auto | NetSpec::Ideal => None,
            NetSpec::Uniform { latency_us, gbps } => {
                Some(Arc::new(UniformNet { latency: Duration::from_micros(latency_us), gbps }))
            }
            NetSpec::FatTree { hop_us, gbps, oversub } => Some(Arc::new(FatTreeNet::new(
                hosts_per_rack,
                Duration::from_micros(hop_us),
                gbps,
                oversub,
            ))),
        }
    }

    /// Stable kind tag (config JSON round-trips on this).
    pub fn kind(&self) -> &'static str {
        match self {
            NetSpec::Auto => "auto",
            NetSpec::Ideal => "ideal",
            NetSpec::Uniform { .. } => "uniform",
            NetSpec::FatTree { .. } => "fat_tree",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances_monotonically() {
        let clock = SimClock::new();
        let before = clock.now();
        clock.advance(Duration::from_millis(250));
        let after = clock.now();
        assert!(after >= before + Duration::from_millis(250));
        assert_eq!(clock.skew(), Duration::from_millis(250));
        // Clones share the skew.
        let other = clock.clone();
        other.advance(Duration::from_millis(50));
        assert_eq!(clock.skew(), Duration::from_millis(300));
    }

    #[test]
    fn wire_sizes_track_payload() {
        assert_eq!(7u32.wire_bytes(), 4);
        assert_eq!(7u64.wire_bytes(), 8);
        assert_eq!(String::from("abcd").wire_bytes(), 12);
        assert_eq!(().wire_bytes(), 0);
    }

    #[test]
    fn ideal_is_free() {
        let now = Instant::now();
        assert_eq!(IdealNet.delay(0, 1, 1 << 20, now), Duration::ZERO);
    }

    #[test]
    fn uniform_charges_latency_plus_bandwidth() {
        let net = UniformNet { latency: Duration::from_micros(100), gbps: 8 };
        let now = Instant::now();
        // 1000 bytes at 8 gbps = 1000 ns of serialization.
        assert_eq!(net.delay(0, 1, 1000, now), Duration::from_nanos(100_000 + 1000));
        // Self-delivery is free; everything else pays the same price.
        assert_eq!(net.delay(3, 3, 1000, now), Duration::ZERO);
        assert_eq!(net.delay(0, 1, 0, now), Duration::from_micros(100));
    }

    #[test]
    fn fat_tree_rack_mapping() {
        let net = FatTreeNet::new(2, Duration::from_micros(10), 10, 4);
        assert_eq!(net.rack_of(0), 0);
        assert_eq!(net.rack_of(1), 0);
        assert_eq!(net.rack_of(2), 1);
        assert_eq!(net.rack_of(5), 2);
        // Non-host endpoints (coordinators, EP_NONE) attach at rack 0.
        assert_eq!(net.rack_of((1 << 32) | 7), 0);
        assert_eq!(net.rack_of(u64::MAX), 0);
        // hosts_per_rack = 0: one big rack.
        let flat = FatTreeNet::new(0, Duration::from_micros(10), 10, 4);
        assert_eq!(flat.rack_of(0), 0);
        assert_eq!(flat.rack_of(999), 0);
    }

    #[test]
    fn fat_tree_cross_rack_costs_more_than_same_rack() {
        let net = FatTreeNet::new(2, Duration::from_micros(100), 10, 4);
        let now = Instant::now();
        let local = net.delay(0, 1, 1000, now); // same rack
        let remote = net.delay(0, 2, 1000, now); // rack 0 -> rack 1
        // 2 hops + 800ns vs 4 hops + 4*800ns.
        assert_eq!(local, Duration::from_nanos(200_000 + 800));
        assert_eq!(remote, Duration::from_nanos(400_000 + 3200));
        assert!(remote > local);
    }

    #[test]
    fn fat_tree_spine_link_queues_concurrent_transfers() {
        let net = FatTreeNet::new(1, Duration::from_micros(10), 1, 1);
        let now = Instant::now();
        // 1 gbps, 10_000 bytes => 80 us serialization per transfer.
        let first = net.delay(0, 1, 10_000, now);
        let second = net.delay(0, 1, 10_000, now);
        assert_eq!(first, Duration::from_micros(40 + 80));
        // The second transfer waits for the first to clear the link.
        assert_eq!(second, Duration::from_micros(40 + 160));
        // A different rack pair uses its own link: no queuing.
        let other = net.delay(0, 2, 10_000, now);
        assert_eq!(other, Duration::from_micros(40 + 80));
    }

    #[test]
    fn spec_resolution_and_build() {
        // Explicit variants are never overridden by the env.
        assert_eq!(NetSpec::Ideal.resolve(), NetSpec::Ideal);
        assert_eq!(NetSpec::ENV_FAT_TREE.resolve(), NetSpec::ENV_FAT_TREE);
        assert!(NetSpec::Ideal.build(4).is_none(), "ideal installs no model");
        let uni = NetSpec::Uniform { latency_us: 50, gbps: 10 }.build(4).expect("model");
        assert_eq!(uni.name(), "uniform");
        let ft = NetSpec::ENV_FAT_TREE.build(4).expect("model");
        assert_eq!(ft.name(), "fat_tree");
        // Auto resolves to *some* concrete variant (env-dependent).
        assert_ne!(NetSpec::Auto.resolve(), NetSpec::Auto);
        assert_eq!(NetSpec::Auto.kind(), "auto");
        assert_eq!(NetSpec::ENV_UNIFORM.kind(), "uniform");
    }
}
