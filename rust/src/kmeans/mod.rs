//! k-means for meta-HNSW vertex generation (Algorithm 3 line 4) and
//! spherical k-means for MIPS (Algorithm 5 line 5).
//!
//! Lloyd iterations with k-means++ seeding; the assignment step is the
//! dense-score hot spot and is rayon-parallel here. The AOT `kmeans_step`
//! artifact (see `python/compile/model.py`) computes the same weighted
//! partial statistics through the Pallas scorer; [`crate::runtime`] wires
//! it in for the PJRT-backed build path, mirroring the paper's
//! distributed-kmeans workflow where workers reduce partial sums.

use crate::dataset::Dataset;
use crate::error::{PyramidError, Result};
use crate::metric::{self};
use crate::util::rng::Rng;
use crate::util::threads;

/// k-means configuration.
#[derive(Debug, Clone, Copy)]
pub struct KmeansParams {
    pub centers: usize,
    pub max_iters: usize,
    /// Relative improvement in mean squared distance below which we stop.
    pub tol: f64,
    /// Spherical mode: centers re-normalized to unit norm every update
    /// (Algorithm 5's spherical k-means [35]).
    pub spherical: bool,
    pub seed: u64,
}

impl Default for KmeansParams {
    fn default() -> Self {
        KmeansParams { centers: 256, max_iters: 20, tol: 1e-4, spherical: false, seed: 0 }
    }
}

/// Fitted model: centers (row-major dataset) + final assignments.
#[derive(Debug, Clone)]
pub struct KmeansModel {
    pub centers: Dataset,
    pub assignments: Vec<u32>,
    pub inertia: f64,
    pub iters: usize,
}

/// Nearest center (squared L2) of one point.
#[inline]
pub fn nearest_center(centers: &Dataset, p: &[f32]) -> (u32, f32) {
    let mut best = (0u32, f32::MAX);
    for (ci, c) in centers.iter().enumerate() {
        let d = metric::l2_sq_unrolled(p, c);
        if d < best.1 {
            best = (ci as u32, d);
        }
    }
    best
}

/// k-means++ seeding: D^2-weighted center choice for fast convergence.
fn seed_plus_plus(data: &Dataset, k: usize, rng: &mut Rng) -> Vec<f32> {
    let n = data.len();
    let d = data.dim();
    let mut centers = Vec::with_capacity(k * d);
    let first = rng.below(n);
    centers.extend_from_slice(data.get(first));
    let mut dist2: Vec<f32> = (0..n)
        .map(|i| metric::l2_sq_unrolled(data.get(i), data.get(first)))
        .collect();
    for _ in 1..k {
        let total: f64 = dist2.iter().map(|&x| x as f64).sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut t = rng.f64() * total;
            let mut idx = n - 1;
            for (i, &w) in dist2.iter().enumerate() {
                if t < w as f64 {
                    idx = i;
                    break;
                }
                t -= w as f64;
            }
            idx
        };
        let cstart = centers.len();
        centers.extend_from_slice(data.get(pick));
        let new_c = centers[cstart..].to_vec();
        threads::parallel_for_each_mut(&mut dist2, threads::default_parallelism(), |i, dref| {
            let nd = metric::l2_sq_unrolled(data.get(i), &new_c);
            if nd < *dref {
                *dref = nd;
            }
        });
    }
    centers
}

/// Run Lloyd's algorithm. For spherical mode, `data` should already be
/// normalized to unit norm (Algorithm 5 line 4); centers are re-normalized
/// after every update so they stay on the sphere.
pub fn fit(data: &Dataset, params: &KmeansParams) -> Result<KmeansModel> {
    let n = data.len();
    let k = params.centers;
    if k == 0 || k > n {
        return Err(PyramidError::Index(format!(
            "kmeans centers {k} invalid for dataset of {n}"
        )));
    }
    let d = data.dim();
    let mut rng = Rng::seed_from_u64(params.seed ^ 0x5EED);
    let mut centers_buf = seed_plus_plus(data, k, &mut rng);
    if params.spherical {
        for c in centers_buf.chunks_exact_mut(d) {
            metric::normalize_in_place(c);
        }
    }
    let mut centers = Dataset::from_vec(centers_buf, d)?;
    let mut assignments = vec![0u32; n];
    let mut prev_inertia = f64::MAX;
    let mut inertia = 0.0;
    let mut iters = 0;

    for it in 0..params.max_iters {
        iters = it + 1;
        // Assignment step (parallel over points).
        let stats: Vec<(u32, f32)> = threads::parallel_map(n, threads::default_parallelism(), |i| {
            nearest_center(&centers, data.get(i))
        });
        inertia = stats.iter().map(|s| s.1 as f64).sum::<f64>() / n as f64;
        for (i, s) in stats.iter().enumerate() {
            assignments[i] = s.0;
        }
        // Update step.
        let mut sums = vec![0f64; k * d];
        let mut counts = vec![0f64; k];
        for (i, s) in stats.iter().enumerate() {
            let c = s.0 as usize;
            counts[c] += 1.0;
            let row = data.get(i);
            for (j, v) in row.iter().enumerate() {
                sums[c * d + j] += *v as f64;
            }
        }
        let mut new_centers = vec![0f32; k * d];
        for c in 0..k {
            if counts[c] > 0.0 {
                for j in 0..d {
                    new_centers[c * d + j] = (sums[c * d + j] / counts[c]) as f32;
                }
            } else {
                // Empty cluster: re-seed at the point farthest from its
                // center to avoid dead centroids.
                let far = stats
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                new_centers[c * d..(c + 1) * d].copy_from_slice(data.get(far));
            }
            if params.spherical {
                metric::normalize_in_place(&mut new_centers[c * d..(c + 1) * d]);
            }
        }
        centers = Dataset::from_vec(new_centers, d)?;
        if prev_inertia.is_finite() && (prev_inertia - inertia).abs() / prev_inertia.max(1e-12) < params.tol {
            break;
        }
        prev_inertia = inertia;
    }
    Ok(KmeansModel { centers, assignments, inertia, iters })
}

/// Per-center sample counts — the vertex weights Pyramid uses for balanced
/// partitioning (Algorithm 3: "weight ... set as the number of items it
/// has from X'").
pub fn center_weights(model: &KmeansModel) -> Vec<f64> {
    let mut w = vec![0f64; model.centers.len()];
    for &a in &model.assignments {
        w[a as usize] += 1.0;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::SyntheticSpec;

    #[test]
    fn rejects_bad_k() {
        let ds = SyntheticSpec::uniform(10, 4, 1).generate();
        assert!(fit(&ds, &KmeansParams { centers: 0, ..Default::default() }).is_err());
        assert!(fit(&ds, &KmeansParams { centers: 11, ..Default::default() }).is_err());
    }

    #[test]
    fn recovers_separated_clusters() {
        // 3 well-separated blobs; k=3 must reach near-zero inertia.
        let mut buf = Vec::new();
        let mut rng = Rng::seed_from_u64(9);
        for c in 0..3 {
            for _ in 0..100 {
                buf.push(c as f32 * 100.0 + rng.f32_range(-0.5, 0.5));
                buf.push(c as f32 * -50.0 + rng.f32_range(-0.5, 0.5));
            }
        }
        let ds = Dataset::from_vec(buf, 2).unwrap();
        let m = fit(&ds, &KmeansParams { centers: 3, max_iters: 30, ..Default::default() }).unwrap();
        assert!(m.inertia < 1.0, "inertia {}", m.inertia);
        // All points in a blob share an assignment.
        for blob in 0..3 {
            let a0 = m.assignments[blob * 100];
            for i in 0..100 {
                assert_eq!(m.assignments[blob * 100 + i], a0);
            }
        }
    }

    #[test]
    fn inertia_decreases() {
        let ds = SyntheticSpec::deep_like(1000, 16, 17).generate();
        let m1 = fit(&ds, &KmeansParams { centers: 8, max_iters: 1, ..Default::default() }).unwrap();
        let m10 = fit(&ds, &KmeansParams { centers: 8, max_iters: 10, ..Default::default() }).unwrap();
        assert!(m10.inertia <= m1.inertia + 1e-9);
    }

    #[test]
    fn spherical_centers_unit_norm() {
        let ds = SyntheticSpec::tiny_like(500, 12, 23).generate().normalized();
        let m = fit(&ds, &KmeansParams { centers: 16, spherical: true, ..Default::default() }).unwrap();
        for c in m.centers.iter() {
            assert!((metric::norm(c) - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn weights_sum_to_n() {
        let ds = SyntheticSpec::deep_like(400, 8, 31).generate();
        let m = fit(&ds, &KmeansParams { centers: 10, ..Default::default() }).unwrap();
        let w = center_weights(&m);
        assert_eq!(w.iter().sum::<f64>() as usize, 400);
    }

    #[test]
    fn deterministic() {
        let ds = SyntheticSpec::deep_like(300, 8, 41).generate();
        let p = KmeansParams { centers: 6, ..Default::default() };
        let a = fit(&ds, &p).unwrap();
        let b = fit(&ds, &p).unwrap();
        assert_eq!(a.assignments, b.assignments);
    }
}
