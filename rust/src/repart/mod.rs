//! Self-healing partition plane (ROADMAP: drift-triggered re-partition).
//!
//! Pyramid's performance story rests on partitions holding *similar*
//! items so queries visit few sub-datasets — but sustained ingest drifts
//! the data away from the construction-time k-means layout, silently
//! eroding routing quality and load balance. This module is the decision
//! and planning half of the recovery loop:
//!
//! * [`DriftDetector`] watches per-partition signals ([`PartitionSignal`]:
//!   row-count skew plus the mean insert-distance-to-centroid that
//!   [`crate::ingest::LiveIndex`] tracks incrementally) behind the same
//!   hysteresis discipline as the elasticity controller
//!   ([`crate::load::ElasticityController`]): `high_ticks` consecutive
//!   drifted observations trigger, a trigger starts a `cooldown_ticks`
//!   refractory period.
//! * [`plan_migration`] re-clusters a pooled sample of the live rows
//!   through the existing [`crate::kmeans`] / meta-HNSW / min-cut
//!   machinery (Algorithm 3 lines 3-6, re-run online) and emits a
//!   [`MigrationPlan`]: a fresh routing table plus the row→partition
//!   move set that realizes it.
//! * [`MigMsg`] is the plan's journal form. The execution driver
//!   ([`crate::cluster::SimCluster::enable_repartition`]) journals
//!   `Planned` to the retained `mig` log **before** touching any data
//!   (the same durability seam as the async-job journal) and `Done`
//!   after commit, so a coordinator or executor killed mid-migration
//!   resumes from the journal: every phase — copy (dup-gid guard),
//!   commit (overlay swap is a no-op when already promoted), retire
//!   (deletes are idempotent) — is safe to re-run.
//!
//! The driver itself lives in [`crate::cluster`], following the
//! elasticity precedent: this module stays pure (no threads, no broker
//! handles), so the detector and planner are unit-testable in isolation
//! and the off state adds zero work to any hot path.

use crate::config::RepartConfig;
use crate::dataset::Dataset;
use crate::error::Result;
use crate::hnsw::{Hnsw, HnswParams};
use crate::kmeans::{self, KmeansParams};
use crate::meta::Router;
use crate::metric::Metric;
use crate::net::WireSize;
use crate::partition::{self, CsrGraph, PartitionParams};
use crate::types::{PartitionId, VectorId};
use std::sync::Arc;

/// Retained broker topic the migration journal lives on. Log semantics
/// (`publish_log` / `log_tailer`), never truncated mid-migration: the
/// journal IS the crash-safety story.
pub const MIG_TOPIC: &str = "mig";

/// One partition's health observation for a detector tick.
#[derive(Debug, Clone, Copy)]
pub struct PartitionSignal {
    pub partition: PartitionId,
    /// Live (non-tombstoned) rows currently stored.
    pub rows: usize,
    /// `(inserts observed, mean L2 distance to the construction-time
    /// centroid)` from [`crate::ingest::LiveIndex::drift_stats`]; `None`
    /// until the partition has absorbed any inserts.
    pub drift: Option<(u64, f64)>,
}

/// Inserts a partition must have absorbed before its centroid-distance
/// signal is trusted (a handful of rows is noise, not drift).
const MIN_DRIFT_SAMPLES: u64 = 16;

/// Hysteresis-gated drift detector, in the style of
/// [`crate::load::ElasticityController`]: a single bad observation never
/// triggers a migration; `high_ticks` consecutive ones do, and a trigger
/// starts a cooldown so back-to-back migrations cannot thrash.
pub struct DriftDetector {
    cfg: RepartConfig,
    streak: u32,
    cooldown: u32,
}

impl DriftDetector {
    pub fn new(cfg: RepartConfig) -> Self {
        DriftDetector { cfg, streak: 0, cooldown: 0 }
    }

    /// Whether this instant's signals look drifted, and why. Pure — no
    /// hysteresis state involved.
    ///
    /// Two independent tripwires:
    /// * **skew** — the largest partition holds more than `skew_ratio`
    ///   times the mean partition size (routing mass is piling up in one
    ///   place);
    /// * **drift** — one partition's mean insert-distance-to-centroid
    ///   exceeds `drift_ratio` times the mean of all partitions'
    ///   (its arrivals are far from its center *relative to its peers*,
    ///   i.e. the construction-time assignment is misrouting them).
    pub fn observe(&self, signals: &[PartitionSignal]) -> Option<String> {
        if signals.is_empty() {
            return None;
        }
        let total: usize = signals.iter().map(|s| s.rows).sum();
        if total > 0 {
            let mean = total as f64 / signals.len() as f64;
            if let Some(worst) = signals.iter().max_by_key(|s| s.rows) {
                if worst.rows as f64 > self.cfg.skew_ratio * mean {
                    return Some(format!(
                        "skew: partition {} holds {} rows vs mean {mean:.0}",
                        worst.partition, worst.rows
                    ));
                }
            }
        }
        let means: Vec<(PartitionId, f64)> = signals
            .iter()
            .filter_map(|s| match s.drift {
                Some((n, mean)) if n >= MIN_DRIFT_SAMPLES => Some((s.partition, mean)),
                _ => None,
            })
            .collect();
        if means.len() >= 2 {
            let global = means.iter().map(|(_, m)| m).sum::<f64>() / means.len() as f64;
            if global > 0.0 {
                for &(p, m) in &means {
                    if m > self.cfg.drift_ratio * global {
                        return Some(format!(
                            "drift: partition {p} mean insert distance {m:.3} vs global {global:.3}"
                        ));
                    }
                }
            }
        }
        None
    }

    /// One detector tick. Returns the trigger reason when `high_ticks`
    /// consecutive drifted observations have accumulated outside a
    /// cooldown; triggering resets the streak and starts the cooldown
    /// immediately (the migration it requests takes time — re-triggering
    /// under it would thrash).
    pub fn tick(&mut self, signals: &[PartitionSignal]) -> Option<String> {
        self.cooldown = self.cooldown.saturating_sub(1);
        match self.observe(signals) {
            Some(reason) => {
                self.streak += 1;
                if self.streak >= self.cfg.high_ticks && self.cooldown == 0 {
                    self.streak = 0;
                    self.cooldown = self.cfg.cooldown_ticks;
                    Some(reason)
                } else {
                    None
                }
            }
            None => {
                self.streak = 0;
                None
            }
        }
    }

    /// Record an externally-driven migration (a forced trigger, a chaos
    /// timeline action): resets the streak and starts the cooldown, so
    /// the detector backs off exactly as if it had triggered itself.
    pub fn note_migrated(&mut self) {
        self.streak = 0;
        self.cooldown = self.cfg.cooldown_ticks;
    }
}

/// One row the migration relocates. Carrying the vector makes the
/// journaled plan self-contained: a crash-resumed copy phase re-streams
/// straight from the journal without consulting any (possibly dead)
/// source replica.
#[derive(Debug, Clone)]
pub struct RowMove {
    pub gid: VectorId,
    pub from: PartitionId,
    pub to: PartitionId,
    pub vector: Arc<Vec<f32>>,
}

/// A planned re-partition: the re-clustered routing table plus the move
/// set that realizes it. Journaled to [`MIG_TOPIC`] before execution.
pub struct MigrationPlan {
    pub id: u64,
    /// Routing epoch the plan was computed against (staleness guard: a
    /// resumed plan against a newer epoch is discarded, keeping the
    /// chaos invariant "epoch divergence ≤ 1" honest).
    pub from_epoch: u64,
    pub metric: Metric,
    pub partitions: usize,
    /// Re-clustered meta-HNSW over the new centers.
    pub meta: Arc<Hnsw>,
    /// Partition id of each new meta vertex (min-cut output).
    pub meta_partition: Arc<Vec<u32>>,
    pub moves: Vec<RowMove>,
}

impl MigrationPlan {
    /// The routing table this plan installs (the dual-serve overlay, and
    /// after commit the base table).
    pub fn router(&self) -> Router {
        Router::new(self.meta.clone(), self.meta_partition.clone(), self.partitions)
    }
}

impl std::fmt::Debug for MigrationPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MigrationPlan")
            .field("id", &self.id)
            .field("from_epoch", &self.from_epoch)
            .field("partitions", &self.partitions)
            .field("meta_size", &self.meta.len())
            .field("moves", &self.moves.len())
            .finish()
    }
}

/// Migration-journal record ([`MIG_TOPIC`]). `Planned` is written before
/// any data moves; `Done` after commit + retire. Resume scans the log
/// from its start: a `Planned` without a matching `Done` is re-executed
/// (every phase is idempotent — see the module docs).
#[derive(Clone)]
pub enum MigMsg {
    Planned(Arc<MigrationPlan>),
    Done { plan_id: u64 },
}

impl WireSize for MigMsg {
    /// The plan's serialized form: header + the new meta graph's vectors
    /// and partition map + each move's (gid, from, to, vector).
    fn wire_bytes(&self) -> usize {
        match self {
            MigMsg::Planned(p) => {
                8 + 8
                    + p.meta.len() * p.meta.dim() * 4
                    + p.meta_partition.len() * 4
                    + p.moves.iter().map(|m| 8 + 2 + 2 + m.vector.len() * 4).sum::<usize>()
            }
            MigMsg::Done { .. } => 8,
        }
    }
}

/// Re-cluster the live rows and plan the moves (Algorithm 3 lines 3-6,
/// re-run online over a pooled per-partition sample).
///
/// `rows_by_partition` is a consistent snapshot of every partition's
/// live rows ([`crate::ingest::LiveIndex::export_rows`]); `meta_size`
/// mirrors the construction-time center count. Returns `Ok(None)` when
/// fewer than `cfg.min_moves` rows would relocate — the layout is close
/// enough that a migration's churn isn't worth it.
///
/// Deterministic for a fixed `seed` (strided sampling, seeded k-means /
/// HNSW / min-cut), so a crash-resumed planner would reproduce the same
/// plan — though resume never re-plans, it replays the journaled one.
pub fn plan_migration(
    plan_id: u64,
    from_epoch: u64,
    rows_by_partition: &[Vec<(VectorId, Vec<f32>)>],
    metric: Metric,
    meta_size: usize,
    cfg: &RepartConfig,
    seed: u64,
) -> Result<Option<MigrationPlan>> {
    let w = rows_by_partition.len();
    let dim = rows_by_partition.iter().flatten().map(|(_, v)| v.len()).next();
    let Some(dim) = dim else { return Ok(None) }; // no live rows anywhere
    // Pooled sample: up to `sample_per_partition` strided rows from each
    // partition, so every partition's distribution is represented
    // regardless of skew.
    let mut flat: Vec<f32> = Vec::new();
    let mut sampled = 0usize;
    for rows in rows_by_partition {
        if rows.is_empty() {
            continue;
        }
        let step = (rows.len() / cfg.sample_per_partition.max(1)).max(1);
        for (_, v) in rows.iter().step_by(step).take(cfg.sample_per_partition) {
            flat.extend_from_slice(v);
            sampled += 1;
        }
    }
    let sample = Dataset::from_vec(flat, dim)?;
    let m = meta_size.min(sampled).max(w);
    // 1. k-means over the pooled sample (spherical for MIPS, matching
    // the construction-time choice).
    let km = kmeans::fit(
        &sample,
        &KmeansParams {
            centers: m,
            max_iters: 15,
            tol: 1e-3,
            spherical: metric == Metric::Ip,
            seed,
        },
    )?;
    let weights = kmeans::center_weights(&km);
    // 2. Meta-HNSW over the new centers.
    let meta_params = HnswParams { seed: seed ^ 0x3E7A, ..HnswParams::default() };
    let meta = Hnsw::build(km.centers.clone(), metric, meta_params)?;
    // 3. Min-cut partition of the meta bottom layer, weighted by sample
    // mass so the new sub-datasets balance.
    let lists: Vec<Vec<u32>> =
        (0..m as u32).map(|u| meta.bottom_neighbors(u).to_vec()).collect();
    let graph = CsrGraph::from_directed(&lists, weights)?;
    let parts = partition::partition(
        &graph,
        &PartitionParams { parts: w, epsilon: 0.05, seed, ..Default::default() },
    )?;
    // 4. Re-assign every live row; rows whose new partition differs are
    // the move set.
    let assign_ef = 32.max(meta_params.m);
    let mut moves = Vec::new();
    for (p, rows) in rows_by_partition.iter().enumerate() {
        for (gid, v) in rows {
            let hit = meta.search(v, 1, assign_ef);
            let to = parts.part[hit[0].id as usize] as PartitionId;
            if to != p as PartitionId {
                moves.push(RowMove {
                    gid: *gid,
                    from: p as PartitionId,
                    to,
                    vector: Arc::new(v.clone()),
                });
            }
        }
    }
    if moves.len() < cfg.min_moves {
        return Ok(None);
    }
    Ok(Some(MigrationPlan {
        id: plan_id,
        from_epoch,
        metric,
        partitions: w,
        meta: Arc::new(meta),
        meta_partition: Arc::new(parts.part),
        moves,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::SyntheticSpec;

    fn cfg() -> RepartConfig {
        RepartConfig {
            enabled: true,
            high_ticks: 3,
            cooldown_ticks: 5,
            min_moves: 64,
            ..RepartConfig::default()
        }
    }

    fn calm(parts: usize) -> Vec<PartitionSignal> {
        (0..parts)
            .map(|p| PartitionSignal {
                partition: p as PartitionId,
                rows: 1_000,
                drift: Some((100, 1.0)),
            })
            .collect()
    }

    #[test]
    fn detector_hysteresis_streak_and_cooldown() {
        let mut det = DriftDetector::new(cfg());
        let mut skewed = calm(4);
        skewed[2].rows = 5_000; // 5000 > 2.0 * mean(2000)
        // Calm ticks never trigger and reset the streak.
        assert!(det.tick(&calm(4)).is_none());
        // Two drifted ticks: streak building, below high_ticks.
        assert!(det.tick(&skewed).is_none());
        assert!(det.tick(&skewed).is_none());
        // A calm tick resets the streak — the next two drifted ticks
        // must NOT trigger.
        assert!(det.tick(&calm(4)).is_none());
        assert!(det.tick(&skewed).is_none());
        assert!(det.tick(&skewed).is_none());
        // Third consecutive drifted tick triggers, with the skew reason.
        let reason = det.tick(&skewed).expect("high_ticks reached");
        assert!(reason.contains("skew"), "{reason}");
        // Cooldown: sustained drift cannot re-trigger until it expires.
        for _ in 0..4 {
            assert!(det.tick(&skewed).is_none(), "cooldown must suppress");
        }
        // Cooldown expired (5 ticks elapsed) and the streak is long since
        // rebuilt: the next drifted tick re-triggers.
        assert!(det.tick(&skewed).is_some());
    }

    #[test]
    fn detector_centroid_drift_tripwire_and_min_samples() {
        let det = DriftDetector::new(cfg());
        let mut s = calm(4);
        // Partition 3's arrivals sit far from its centroid vs peers.
        s[3].drift = Some((100, 4.0)); // global mean 1.75, 4.0 > 1.5 * 1.75
        let reason = det.observe(&s).expect("drift tripwire");
        assert!(reason.contains("drift: partition 3"), "{reason}");
        // The same mean off a handful of inserts is noise, not drift.
        s[3].drift = Some((MIN_DRIFT_SAMPLES - 1, 4.0));
        assert!(det.observe(&s).is_none());
        // note_migrated starts the refractory period.
        let mut det = DriftDetector::new(cfg());
        det.note_migrated();
        s[3].drift = Some((100, 4.0));
        for _ in 0..4 {
            assert!(det.tick(&s).is_none(), "cooldown after note_migrated");
        }
    }

    /// Planner end-to-end on a deliberately scrambled layout: clustered
    /// data dealt round-robin across partitions must yield a large move
    /// set; the same data laid out by the plan itself must then be close
    /// enough that re-planning stays under `min_moves`.
    #[test]
    fn plan_migration_moves_scrambled_rows_and_is_stable_when_clean() {
        let data = SyntheticSpec::deep_like(2_000, 16, 91).generate();
        let w = 4;
        // Round-robin: every partition holds a uniform mix of all
        // clusters — maximal drift from any similarity layout.
        let mut scrambled: Vec<Vec<(VectorId, Vec<f32>)>> = vec![Vec::new(); w];
        for i in 0..data.len() {
            scrambled[i % w].push((i as VectorId, data.get(i).to_vec()));
        }
        let c = cfg();
        let plan = plan_migration(1, 0, &scrambled, Metric::L2, 32, &c, 7)
            .unwrap()
            .expect("scrambled layout must demand a migration");
        assert_eq!(plan.partitions, w);
        assert_eq!(plan.id, 1);
        // Round-robin vs a 4-way similarity layout: ~3/4 of rows move.
        assert!(plan.moves.len() > data.len() / 2, "only {} moves", plan.moves.len());
        for mv in &plan.moves {
            assert_ne!(mv.from, mv.to);
            assert!((mv.to as usize) < w);
            assert_eq!(mv.vector.len(), 16);
        }
        // The plan's router must route each moved vector to its `to`
        // partition (branch=1 insert rule) — spot-check a stride.
        let router = plan.router();
        for mv in plan.moves.iter().step_by(97) {
            let parts = router.route(&mv.vector, 1, 64);
            assert_eq!(parts, vec![mv.to], "gid {}", mv.gid);
        }
        // Apply the moves, re-plan: the healed layout is stable.
        let mut healed: Vec<Vec<(VectorId, Vec<f32>)>> = vec![Vec::new(); w];
        let moved: std::collections::HashMap<VectorId, PartitionId> =
            plan.moves.iter().map(|m| (m.gid, m.to)).collect();
        for (p, rows) in scrambled.iter().enumerate() {
            for (gid, v) in rows {
                let dest = moved.get(gid).copied().unwrap_or(p as PartitionId);
                healed[dest as usize].push((*gid, v.clone()));
            }
        }
        let replan = plan_migration(2, 1, &healed, Metric::L2, 32, &c, 7).unwrap();
        if let Some(rp) = &replan {
            assert!(
                rp.moves.len() < plan.moves.len() / 4,
                "healed layout still wants {} of {} moves",
                rp.moves.len(),
                plan.moves.len()
            );
        }
        // Journal form prices the self-contained plan.
        let msg = MigMsg::Planned(Arc::new(plan));
        assert!(msg.wire_bytes() > 16 * 4 * 500, "plan wire size implausibly small");
        assert_eq!((MigMsg::Done { plan_id: 1 }).wire_bytes(), 8);
    }

    #[test]
    fn plan_migration_empty_and_below_floor() {
        let c = cfg();
        // No rows at all: nothing to plan.
        let empty: Vec<Vec<(VectorId, Vec<f32>)>> = vec![Vec::new(); 4];
        assert!(plan_migration(1, 0, &empty, Metric::L2, 32, &c, 7).unwrap().is_none());
        // A handful of rows: any conceivable move set is under min_moves.
        let data = SyntheticSpec::deep_like(40, 8, 3).generate();
        let mut few: Vec<Vec<(VectorId, Vec<f32>)>> = vec![Vec::new(); 4];
        for i in 0..data.len() {
            few[i % 4].push((i as VectorId, data.get(i).to_vec()));
        }
        assert!(plan_migration(1, 0, &few, Metric::L2, 16, &c, 7).unwrap().is_none());
    }
}
