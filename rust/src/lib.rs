//! # Pyramid — distributed similarity search on HNSW
//!
//! Reproduction of *Pyramid: A General Framework for Distributed Similarity
//! Search* (Deng, Yan, Ng, Jiang, Cheng; 2019). Pyramid builds a small
//! **meta-HNSW** over k-means centers of a dataset sample, partitions its
//! bottom layer into balanced min-cut graph partitions, assigns every item
//! to the sub-dataset of its nearest meta vertex, and builds one
//! **sub-HNSW** per partition. Queries search the meta-HNSW first and are
//! dispatched only to the sub-HNSWs whose partitions contain one of the
//! query's top-`K` meta neighbors — keeping the per-query *access rate*
//! well below 1 and raising cluster throughput >2x over naive random
//! partitioning.
//!
//! ## Crate layout (three-layer architecture, DESIGN.md)
//!
//! Layer 3 (this crate) owns all coordination: routing ([`meta`],
//! [`coordinator`]), the message broker ([`broker`], a Kafka substitute),
//! the lock registry ([`registry`], a Zookeeper substitute), the simulated
//! cluster ([`cluster`]) and the public API ([`api`], mirroring the paper's
//! Listings 1–3). Layers 2/1 (JAX graph + Pallas kernel) are compiled
//! AOT to `artifacts/*.hlo.txt` and executed from [`runtime`] via PJRT.
//!
//! ## Quickstart
//!
//! ```ignore
//! use pyramid::prelude::*;
//! let data = SyntheticSpec::deep_like(100_000, 96, 7).generate();
//! let cfg = IndexConfig { partitions: 10, ..IndexConfig::default() };
//! let index = PyramidIndex::build(&data, Metric::L2, &cfg).unwrap();
//! let hits = index.search(data.get(0), &QueryParams { k: 10, branch: 4, ..Default::default() });
//! assert_eq!(hits.len(), 10);
//! ```

pub mod api;
pub mod baselines;
pub mod bench_harness;
pub mod broker;
pub mod bruteforce;
pub mod chaos;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod error;
pub mod executor;
pub mod hnsw;
pub mod ingest;
pub mod kmeans;
pub mod load;
pub mod meta;
pub mod metric;
pub mod net;
pub mod obs;
pub mod partition;
pub mod quant;
pub mod registry;
pub mod repart;
pub mod runtime;
pub mod stats;
pub mod types;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::api::{Coordinator, Executor, GraphConstructor};
    pub use crate::baselines::{DistributedKdForest, KdForest, NaiveIndex};
    pub use crate::bench_harness::{drive_cluster, precision_at_k, BenchRecorder, LatencyRecorder, TablePrinter, Workload};
    pub use crate::chaos::runner::{harness_index, run_schedule, run_schedule_on, ChaosReport};
    pub use crate::chaos::schedule::ChaosSpec;
    pub use crate::chaos::{ChaosSnapshot, FaultPlan, FaultSpec};
    pub use crate::cluster::{ClusterConfig, SimCluster};
    pub use crate::config::{ClusterTopology, IndexConfig, PyramidConfig, QueryParams, RepartConfig};
    pub use crate::coordinator::{CoordinatorConfig, HedgeConfig};
    pub use crate::dataset::{Dataset, SyntheticKind, SyntheticSpec};
    pub use crate::error::{PyramidError, Result};
    pub use crate::hnsw::{Hnsw, HnswParams, NestedHnsw};
    pub use crate::ingest::{IngestConfig, IngestGateway, LiveIndex};
    pub use crate::load::{run_trace, ControllerConfig, LoadConfig, LoadReport, TraceSpec};
    pub use crate::meta::{PyramidIndex, Router};
    pub use crate::metric::Metric;
    pub use crate::net::{FatTreeNet, IdealNet, NetModel, NetSpec, SimClock, UniformNet, WireSize};
    pub use crate::obs::{MetricsRegistry, Obs, ObsSpec, Scrape, TraceId, TraceTree, Tracer};
    pub use crate::quant::{QuantPlane, Sq8Codec};
    pub use crate::repart::{DriftDetector, MigrationPlan, PartitionSignal};
    pub use crate::types::{Neighbor, QueryMetrics, QueryResult, UpdateOp, VectorId};
}
