//! SQ8 scalar quantization: the compressed scoring tier (perf_opt PR 5).
//!
//! The HNSW walk is memory-bandwidth bound — every hop streams random f32
//! rows through the kernels in [`crate::metric`], so the dataset's
//! resident size, not FLOPs, caps throughput and the per-machine
//! partition size. This module shrinks the walk's working set 4× by
//! scoring over 1-byte codes and leaves exactness to a bounded re-rank:
//!
//! * [`Sq8Codec`] — a **per-dimension min/max affine codec** trained on a
//!   partition's rows: per-dimension offsets `lo_j = min_j` with one
//!   shared step `s = max_j(max_j − min_j) / 255`, so `x_j ≈ lo_j + s·c_j`
//!   with `c_j ∈ [0, 255]`. The step is shared across dimensions (uniform
//!   absolute resolution — the right trade for distances, where
//!   wide-range dimensions dominate) because that is what lets the
//!   distance algebra factor into *pure integer* dot products:
//!   queries are encoded through the same codec once per search, and
//!
//!   - `‖q̂ − x̂‖² = s² · Σ (d_j − c_j)²` (offsets cancel — one integer L2),
//!   - `q̂·x̂ = Σlo² + s·(Σlo_j·d_j + Σlo_j·c_j) + s² · Σ d_j·c_j`, where
//!     `Σlo_j·c_j` is precomputed per row at encode time,
//!   - cosine divides the reconstructed dot by precomputed decoded norms.
//!
//! * Integer kernels ([`idot`], [`il2`]) — runtime-dispatched AVX2
//!   (`cvtepu8_epi16` + `madd_epi16`: the `maddubs`/VNNI-free shape that
//!   is fast on every AVX2 part and fully stable-toolchain), NEON
//!   (`vmull_u8` + `vpadal`), and a 16-lane unrolled scalar fallback —
//!   behind the same probe-once dispatch and `PYRAMID_FORCE_SCALAR` pin
//!   as the f32 tier. Integer arithmetic is exact, so all three tiers
//!   return **identical** values (pinned bitwise by the tests below),
//!   and the only approximation anywhere is the codec itself.
//!
//! * [`QuantPlane`] — the codes for a frozen graph's rows, laid out
//!   beside the CSR in fixed-stride 32-byte-aligned blocks (stride =
//!   `d` rounded up to 32), so the walk's block addressing and software
//!   prefetch carry over from the f32 plane unchanged. Per-row `Σlo·c`
//!   and decoded-norm correction floats ride along (8 bytes/row).
//!
//! Quantized search drives the *walk* with approximate scores and then
//! re-ranks the best `refine_k` beam entries with the exact f32 kernels
//! (the rows are retained), so recall impact is bounded by beam ordering
//! only — see [`crate::hnsw`] for the walk integration.

use crate::dataset::Dataset;
use crate::metric::Metric;
use crate::util::aligned::AlignedU8;

/// Code rows are padded to this many bytes so every row starts on a
/// 32-byte boundary of the (32-byte-aligned) plane.
pub const CODE_ALIGN: usize = 32;

/// Fixed code-row stride for dimension `d`: `d` rounded up to 32 bytes.
#[inline]
pub fn code_stride(d: usize) -> usize {
    d.div_ceil(CODE_ALIGN) * CODE_ALIGN
}

// ---------------------------------------------------------------------------
// Integer kernels
// ---------------------------------------------------------------------------

/// A u8×u8 reduction kernel (dot or squared L2 over code vectors).
type IKernel = fn(&[u8], &[u8]) -> u32;

/// Pick the integer dot kernel once (see [`crate::metric`]'s dispatch —
/// same probe, same `PYRAMID_FORCE_SCALAR` pin, memoized by std).
#[inline]
fn idot_kernel() -> IKernel {
    #[cfg(target_arch = "x86_64")]
    {
        if !crate::metric::force_scalar() && std::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 presence just verified at runtime.
            return |a, b| unsafe { x86::idot_avx2(a, b) };
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if !crate::metric::force_scalar() && std::arch::is_aarch64_feature_detected!("neon") {
            // SAFETY: NEON presence just verified at runtime.
            return |a, b| unsafe { neon::idot_neon(a, b) };
        }
    }
    idot_unrolled
}

/// Pick the integer squared-L2 kernel once (see [`idot_kernel`]).
#[inline]
fn il2_kernel() -> IKernel {
    #[cfg(target_arch = "x86_64")]
    {
        if !crate::metric::force_scalar() && std::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 presence just verified at runtime.
            return |a, b| unsafe { x86::il2_avx2(a, b) };
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if !crate::metric::force_scalar() && std::arch::is_aarch64_feature_detected!("neon") {
            // SAFETY: NEON presence just verified at runtime.
            return |a, b| unsafe { neon::il2_neon(a, b) };
        }
    }
    il2_unrolled
}

/// Integer dot product of two code vectors, runtime-dispatched. Exact —
/// every tier returns the same value.
#[inline]
pub fn idot(a: &[u8], b: &[u8]) -> u32 {
    idot_kernel()(a, b)
}

/// Integer squared L2 distance of two code vectors, runtime-dispatched.
#[inline]
pub fn il2(a: &[u8], b: &[u8]) -> u32 {
    il2_kernel()(a, b)
}

/// Portable integer dot: 16 u32 accumulator lanes over `chunks_exact`,
/// auto-vectorizable. Oracle for the SIMD tiers (which must match it
/// bit-for-bit — integer arithmetic has no reassociation error).
#[inline]
pub fn idot_unrolled(a: &[u8], b: &[u8]) -> u32 {
    let n = a.len().min(b.len());
    let mut acc = [0u32; 16];
    let ca = a[..n].chunks_exact(16);
    let cb = b[..n].chunks_exact(16);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (x, y) in ca.zip(cb) {
        for l in 0..16 {
            acc[l] += x[l] as u32 * y[l] as u32;
        }
    }
    let mut s: u32 = acc.iter().sum();
    for (x, y) in ra.iter().zip(rb) {
        s += *x as u32 * *y as u32;
    }
    s
}

/// Portable integer squared L2 (see [`idot_unrolled`]).
#[inline]
pub fn il2_unrolled(a: &[u8], b: &[u8]) -> u32 {
    let n = a.len().min(b.len());
    let mut acc = [0u32; 16];
    let ca = a[..n].chunks_exact(16);
    let cb = b[..n].chunks_exact(16);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (x, y) in ca.zip(cb) {
        for l in 0..16 {
            let d = x[l] as i32 - y[l] as i32;
            acc[l] += (d * d) as u32;
        }
    }
    let mut s: u32 = acc.iter().sum();
    for (x, y) in ra.iter().zip(rb) {
        let d = *x as i32 - *y as i32;
        s += (d * d) as u32;
    }
    s
}

/// AVX2 integer kernels. Codes are zero-extended u8→i16
/// (`cvtepu8_epi16`) and reduced with `madd_epi16` — 16 bytes per step,
/// i32 accumulator lanes. Each madd lane sums two products ≤ 255² so a
/// lane saturates only past ~260k dims; realistic dims are ≤ 4096.
/// No `maddubs` (whose i8 operand would force an offset dance) and no
/// AVX-512/VNNI (not on stable), per the dispatch contract in the module
/// docs.
#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    #[inline]
    unsafe fn hsum_epi32(v: __m256i) -> u32 {
        let q = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256::<1>(v));
        let h = _mm_add_epi32(q, _mm_unpackhi_epi64(q, q));
        let s = _mm_add_epi32(h, _mm_shuffle_epi32::<0x55>(h));
        _mm_cvtsi128_si32(s) as u32
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn idot_avx2(a: &[u8], b: &[u8]) -> u32 {
        let n = a.len().min(b.len());
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 16 <= n {
            let va = _mm256_cvtepu8_epi16(_mm_loadu_si128(pa.add(i) as *const __m128i));
            let vb = _mm256_cvtepu8_epi16(_mm_loadu_si128(pb.add(i) as *const __m128i));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vb));
            i += 16;
        }
        let mut sum = hsum_epi32(acc);
        while i < n {
            sum += *pa.add(i) as u32 * *pb.add(i) as u32;
            i += 1;
        }
        sum
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn il2_avx2(a: &[u8], b: &[u8]) -> u32 {
        let n = a.len().min(b.len());
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 16 <= n {
            let va = _mm256_cvtepu8_epi16(_mm_loadu_si128(pa.add(i) as *const __m128i));
            let vb = _mm256_cvtepu8_epi16(_mm_loadu_si128(pb.add(i) as *const __m128i));
            let d = _mm256_sub_epi16(va, vb); // ±255 fits i16
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(d, d));
            i += 16;
        }
        let mut sum = hsum_epi32(acc);
        while i < n {
            let d = *pa.add(i) as i32 - *pb.add(i) as i32;
            sum += (d * d) as u32;
            i += 1;
        }
        sum
    }
}

/// NEON integer kernels: widening `vmull_u8` products folded pairwise
/// into u32 lanes with `vpadalq_u16` — 8 bytes per step, same exactness
/// contract as the AVX2 tier.
#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    /// # Safety
    /// Caller must have verified NEON support at runtime.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn idot_neon(a: &[u8], b: &[u8]) -> u32 {
        let n = a.len().min(b.len());
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = vdupq_n_u32(0);
        let mut i = 0usize;
        while i + 8 <= n {
            let prod = vmull_u8(vld1_u8(pa.add(i)), vld1_u8(pb.add(i))); // u16x8
            acc = vpadalq_u16(acc, prod);
            i += 8;
        }
        let mut sum = vaddvq_u32(acc);
        while i < n {
            sum += *pa.add(i) as u32 * *pb.add(i) as u32;
            i += 1;
        }
        sum
    }

    /// # Safety
    /// Caller must have verified NEON support at runtime.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn il2_neon(a: &[u8], b: &[u8]) -> u32 {
        let n = a.len().min(b.len());
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = vdupq_n_u32(0);
        let mut i = 0usize;
        while i + 8 <= n {
            let d = vabd_u8(vld1_u8(pa.add(i)), vld1_u8(pb.add(i))); // |a-b| u8x8
            acc = vpadalq_u16(acc, vmull_u8(d, d));
            i += 8;
        }
        let mut sum = vaddvq_u32(acc);
        while i < n {
            let d = *pa.add(i) as i32 - *pb.add(i) as i32;
            sum += (d * d) as u32;
            i += 1;
        }
        sum
    }
}

// ---------------------------------------------------------------------------
// Codec
// ---------------------------------------------------------------------------

/// Per-dimension min/max affine SQ8 codec (see the module docs for why
/// the step is shared across dimensions).
#[derive(Debug, Clone)]
pub struct Sq8Codec {
    /// Per-dimension offset (trained minimum).
    lo: Vec<f32>,
    /// Per-dimension trained maximum (kept for introspection/round-trip
    /// error accounting; decode only needs `lo` and `step`).
    hi: Vec<f32>,
    /// Shared quantization step (`> 0`; 1.0 for a degenerate range so
    /// decode stays exact at `lo`).
    step: f32,
    inv_step: f32,
    /// `Σ lo_j²` — the constant term of the reconstructed inner product.
    lo_sq: f32,
}

/// A query encoded through a codec, with its hoisted per-query
/// correction terms — computed once per search, reused for every
/// candidate block.
#[derive(Debug, Clone)]
pub struct Sq8Query {
    pub codes: Vec<u8>,
    /// `Σ lo_j · d_j` over the query's codes.
    pub corr: f32,
    /// Decoded-query Euclidean norm (Angular denominator).
    pub norm: f32,
}

impl Sq8Codec {
    /// Train over an iterator of rows (all of length `d`). Empty input
    /// yields a degenerate codec that encodes everything to 0 and
    /// decodes to 0.0.
    pub fn train<'a, I>(rows: I, d: usize) -> Sq8Codec
    where
        I: IntoIterator<Item = &'a [f32]>,
    {
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        let mut any = false;
        for row in rows {
            any = true;
            for j in 0..d {
                let v = row[j];
                if v < lo[j] {
                    lo[j] = v;
                }
                if v > hi[j] {
                    hi[j] = v;
                }
            }
        }
        if !any {
            lo.iter_mut().for_each(|v| *v = 0.0);
            hi.iter_mut().for_each(|v| *v = 0.0);
        }
        let max_range = lo.iter().zip(&hi).map(|(l, h)| h - l).fold(0.0f32, f32::max);
        let step = if max_range > 0.0 { max_range / 255.0 } else { 1.0 };
        let lo_sq = lo.iter().map(|&v| v as f64 * v as f64).sum::<f64>() as f32;
        Sq8Codec { lo, hi, step, inv_step: 1.0 / step, lo_sq }
    }

    /// Train over every row of a dataset.
    pub fn train_dataset(data: &Dataset) -> Sq8Codec {
        Sq8Codec::train(data.iter(), data.dim())
    }

    pub fn dim(&self) -> usize {
        self.lo.len()
    }

    /// Shared quantization step — the worst-case per-dimension
    /// reconstruction error for in-range values is `step / 2`.
    pub fn step(&self) -> f32 {
        self.step
    }

    /// Trained per-dimension range (introspection).
    pub fn range(&self, j: usize) -> (f32, f32) {
        (self.lo[j], self.hi[j])
    }

    /// Encode one row into `codes` (length >= `dim`; padding bytes past
    /// `dim` are left untouched). Returns the per-row correction pair
    /// `(Σ lo_j·c_j, decoded-row norm)`.
    pub fn encode_into(&self, row: &[f32], codes: &mut [u8]) -> (f32, f32) {
        let d = self.dim();
        debug_assert_eq!(row.len(), d);
        let mut corr = 0.0f64;
        let mut csq = 0u64;
        for j in 0..d {
            let t = (row[j] - self.lo[j]) * self.inv_step;
            let c = t.round().clamp(0.0, 255.0) as u8;
            codes[j] = c;
            corr += self.lo[j] as f64 * c as f64;
            csq += c as u64 * c as u64;
        }
        let corr = corr as f32;
        let norm_sq = self.lo_sq as f64
            + 2.0 * self.step as f64 * corr as f64
            + (self.step as f64 * self.step as f64) * csq as f64;
        (corr, norm_sq.max(0.0).sqrt() as f32)
    }

    /// Decode codes back to f32 (`x̂_j = lo_j + step·c_j`).
    pub fn decode_into(&self, codes: &[u8], out: &mut Vec<f32>) {
        out.clear();
        out.extend(
            self.lo.iter().zip(codes).map(|(&l, &c)| l + self.step * c as f32),
        );
    }

    /// Encode a query once per search (same transform as rows; values
    /// outside the trained range clamp, which the exact re-rank absorbs).
    pub fn prepare_query(&self, q: &[f32]) -> Sq8Query {
        let mut codes = vec![0u8; self.dim()];
        let (corr, norm) = self.encode_into(q, &mut codes);
        Sq8Query { codes, corr, norm }
    }

    /// Reconstructed score of a code row against a prepared query —
    /// the scalar form of the block path in [`Sq8View::score_ids`],
    /// identical arithmetic.
    #[inline]
    pub fn score_codes(
        &self,
        metric: Metric,
        q: &Sq8Query,
        row: &[u8],
        row_corr: f32,
        row_norm: f32,
    ) -> f32 {
        match metric {
            Metric::L2 => -(self.step * self.step * il2(&q.codes, row) as f32),
            Metric::Ip => self.recon_dot(q, row, row_corr),
            Metric::Angular => {
                let d0 = self.recon_dot(q, row, row_corr);
                if q.norm <= 1e-12 || row_norm <= 1e-12 {
                    0.0
                } else {
                    d0 / (q.norm * row_norm)
                }
            }
        }
    }

    #[inline]
    fn recon_dot(&self, q: &Sq8Query, row: &[u8], row_corr: f32) -> f32 {
        self.lo_sq
            + self.step * (q.corr + row_corr)
            + self.step * self.step * idot(&q.codes, row) as f32
    }
}

// ---------------------------------------------------------------------------
// Code plane + borrowed view
// ---------------------------------------------------------------------------

/// Borrowed view over a set of code rows + their corrections — the form
/// the graph walk scores through. Both the frozen plane
/// ([`QuantPlane::view`]) and the live delta's code buffer produce one.
#[derive(Clone, Copy)]
pub struct Sq8View<'a> {
    pub codec: &'a Sq8Codec,
    /// Fixed-stride code rows (`stride` bytes per row).
    pub codes: &'a [u8],
    pub stride: usize,
    /// Per-row `Σ lo_j·c_j`.
    pub corr: &'a [f32],
    /// Per-row decoded norm.
    pub norm: &'a [f32],
}

impl<'a> Sq8View<'a> {
    /// Code row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &'a [u8] {
        &self.codes[i * self.stride..i * self.stride + self.codec.dim()]
    }

    /// Rows in the view.
    pub fn len(&self) -> usize {
        if self.stride == 0 {
            0
        } else {
            self.codes.len() / self.stride
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Software-prefetch code row `i` (one cache line covers a whole
    /// d≤64 row — a quarter of the f32 plane's footprint per hop).
    #[inline(always)]
    pub fn prefetch(&self, i: usize) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: prefetch has no memory effects; any address is allowed.
        unsafe {
            use core::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
            _mm_prefetch::<_MM_HINT_T0>(self.codes.as_ptr().add(i * self.stride) as *const i8);
        }
        #[cfg(not(target_arch = "x86_64"))]
        let _ = i;
    }

    /// Score one row (see [`Sq8Codec::score_codes`]).
    #[inline]
    pub fn score(&self, metric: Metric, q: &Sq8Query, i: usize) -> f32 {
        self.codec.score_codes(metric, q, self.row(i), self.corr[i], self.norm[i])
    }

    /// Score a gathered id block in one pass: the integer kernel is
    /// dispatched once and the per-query corrections are already hoisted
    /// inside `q` — the SQ8 mirror of [`Metric::score_rows`].
    pub fn score_ids(&self, metric: Metric, q: &Sq8Query, ids: &[u32], out: &mut Vec<f32>) {
        out.clear();
        let c = self.codec;
        match metric {
            Metric::L2 => {
                let k = il2_kernel();
                let s2 = c.step * c.step;
                for &v in ids {
                    out.push(-(s2 * k(&q.codes, self.row(v as usize)) as f32));
                }
            }
            Metric::Ip => {
                let k = idot_kernel();
                let s2 = c.step * c.step;
                for &v in ids {
                    let i = v as usize;
                    out.push(
                        c.lo_sq
                            + c.step * (q.corr + self.corr[i])
                            + s2 * k(&q.codes, self.row(i)) as f32,
                    );
                }
            }
            Metric::Angular => {
                let k = idot_kernel();
                let s2 = c.step * c.step;
                for &v in ids {
                    let i = v as usize;
                    let d0 = c.lo_sq
                        + c.step * (q.corr + self.corr[i])
                        + s2 * k(&q.codes, self.row(i)) as f32;
                    let rn = self.norm[i];
                    out.push(if q.norm <= 1e-12 || rn <= 1e-12 { 0.0 } else { d0 / (q.norm * rn) });
                }
            }
        }
    }
}

/// The owned SQ8 plane of a frozen graph: trained codec + every row's
/// codes in 32-byte-aligned fixed-stride blocks, plus the per-row
/// correction floats and the re-rank budget.
#[derive(Debug, Clone)]
pub struct QuantPlane {
    codec: Sq8Codec,
    codes: AlignedU8,
    stride: usize,
    corr: Vec<f32>,
    norm: Vec<f32>,
    /// Exact re-rank budget: how many of the best beam entries are
    /// re-scored with the f32 kernels after the quantized walk. 0 = auto
    /// (4·k at query time). Clamped to ≥ k at use.
    refine_k: usize,
}

impl QuantPlane {
    /// Train a codec on `data` and encode every row.
    pub fn encode_dataset(data: &Dataset, refine_k: usize) -> QuantPlane {
        let codec = Sq8Codec::train_dataset(data);
        let d = data.dim();
        let stride = code_stride(d);
        let mut codes = AlignedU8::with_capacity(data.len() * stride);
        let mut corr = Vec::with_capacity(data.len());
        let mut norm = Vec::with_capacity(data.len());
        let mut rowbuf = vec![0u8; stride];
        for row in data.iter() {
            rowbuf[d..].iter_mut().for_each(|b| *b = 0);
            let (c, n) = codec.encode_into(row, &mut rowbuf);
            codes.extend_from_slice(&rowbuf);
            corr.push(c);
            norm.push(n);
        }
        QuantPlane { codec, codes, stride, corr, norm, refine_k }
    }

    pub fn codec(&self) -> &Sq8Codec {
        &self.codec
    }

    pub fn len(&self) -> usize {
        self.corr.len()
    }

    pub fn is_empty(&self) -> bool {
        self.corr.is_empty()
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Raw code bytes (tests assert the 32-byte alignment contract).
    pub fn codes(&self) -> &[u8] {
        &self.codes
    }

    /// The raw configured re-rank budget (0 = auto) — what a re-freeze
    /// carries into the next plane.
    pub fn refine_k(&self) -> usize {
        self.refine_k
    }

    /// Configured re-rank budget for `k` results: the stored `refine_k`
    /// (auto → 4·k), never below `k`.
    pub fn refine_for(&self, k: usize) -> usize {
        let base = if self.refine_k == 0 { 4 * k } else { self.refine_k };
        base.max(k)
    }

    /// Plane memory footprint: codes + correction floats.
    pub fn bytes(&self) -> usize {
        self.codes.len() + (self.corr.len() + self.norm.len()) * std::mem::size_of::<f32>()
    }

    pub fn view(&self) -> Sq8View<'_> {
        Sq8View {
            codec: &self.codec,
            codes: &self.codes,
            stride: self.stride,
            corr: &self.corr,
            norm: &self.norm,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::SyntheticSpec;

    fn naive_idot(a: &[u8], b: &[u8]) -> u32 {
        a.iter().zip(b).map(|(&x, &y)| x as u32 * y as u32).sum()
    }

    fn naive_il2(a: &[u8], b: &[u8]) -> u32 {
        a.iter().zip(b).map(|(&x, &y)| (x as i32 - y as i32).pow(2) as u32).sum()
    }

    #[test]
    fn integer_kernels_match_naive_all_lengths() {
        // Cover every tail class of the 16-byte SIMD step and the 16-lane
        // scalar unroll, including non-multiples of 32 (satellite).
        for n in 0..70usize {
            let a: Vec<u8> = (0..n).map(|i| (i * 37 + 11) as u8).collect();
            let b: Vec<u8> = (0..n).map(|i| (i * 101 + 3) as u8).collect();
            assert_eq!(idot_unrolled(&a, &b), naive_idot(&a, &b), "idot_unrolled n={n}");
            assert_eq!(il2_unrolled(&a, &b), naive_il2(&a, &b), "il2_unrolled n={n}");
            // Dispatched tiers are exact integer arithmetic: identical.
            assert_eq!(idot(&a, &b), naive_idot(&a, &b), "idot n={n}");
            assert_eq!(il2(&a, &b), naive_il2(&a, &b), "il2 n={n}");
        }
    }

    #[test]
    fn integer_kernels_saturated_inputs() {
        let a = vec![255u8; 67];
        let b = vec![255u8; 67];
        assert_eq!(idot(&a, &b), 255 * 255 * 67);
        assert_eq!(il2(&a, &b), 0);
        let z = vec![0u8; 67];
        assert_eq!(il2(&a, &z), 255 * 255 * 67);
    }

    /// Mirror of the f32 tier's pin: under `PYRAMID_FORCE_SCALAR=1` the
    /// dispatched kernels must be the portable forms. Integer kernels
    /// are exact in every tier, so equality must hold bitwise regardless
    /// — this documents that the env pin also governs this dispatch.
    #[test]
    fn force_scalar_env_pins_integer_dispatch() {
        for n in [7usize, 16, 33, 96, 131] {
            let a: Vec<u8> = (0..n).map(|i| (i * 7) as u8).collect();
            let b: Vec<u8> = (0..n).map(|i| (255 - i * 3) as u8).collect();
            assert_eq!(idot(&a, &b), idot_unrolled(&a, &b), "idot n={n}");
            assert_eq!(il2(&a, &b), il2_unrolled(&a, &b), "il2 n={n}");
        }
    }

    #[test]
    fn codec_roundtrip_error_within_half_step() {
        crate::util::quickcheck::check(100, |g| {
            let d = g.usize_in(1, 131); // non-multiples of 32 included
            let rows: Vec<Vec<f32>> = (0..g.usize_in(2, 20)).map(|_| g.vec_f32(d)).collect();
            let codec = Sq8Codec::train(rows.iter().map(|r| r.as_slice()), d);
            let bound = 0.5 * codec.step() + 1e-5;
            let mut codes = vec![0u8; d];
            let mut back = Vec::new();
            for (ri, row) in rows.iter().enumerate() {
                codec.encode_into(row, &mut codes);
                codec.decode_into(&codes, &mut back);
                for j in 0..d {
                    let err = (row[j] - back[j]).abs();
                    if err > bound {
                        return Err(format!(
                            "row {ri} dim {j}: |{} - {}| = {err} > step/2 = {bound}",
                            row[j], back[j]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn degenerate_constant_dataset_decodes_exactly() {
        let rows = vec![vec![3.5f32, -1.0, 0.0]; 4];
        let codec = Sq8Codec::train(rows.iter().map(|r| r.as_slice()), 3);
        assert_eq!(codec.step(), 1.0);
        let mut codes = vec![0u8; 3];
        codec.encode_into(&rows[0], &mut codes);
        assert_eq!(codes, vec![0, 0, 0]);
        let mut back = Vec::new();
        codec.decode_into(&codes, &mut back);
        assert_eq!(back, rows[0]);
    }

    /// Satellite acceptance: quantized kernel scores equal scoring the
    /// *dequantized* vectors with the scalar f32 kernels, up to float
    /// rounding, and equal scoring the originals within the codec's own
    /// error bound — on non-multiple-of-32 dims, for all three metrics.
    /// (The same property runs under `PYRAMID_FORCE_SCALAR=1` in CI's
    /// scalar-fallback job, covering both dispatch tiers, and compiles
    /// for both the AVX2 and NEON architectures.)
    #[test]
    fn quantized_score_matches_dequantized_scalar_and_error_bound() {
        crate::util::quickcheck::check(150, |g| {
            let d = g.usize_in(1, 100);
            let n = g.usize_in(2, 12);
            let rows: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(d)).collect();
            let q = g.vec_f32(d);
            let metric = *g.choose(&[Metric::L2, Metric::Angular, Metric::Ip]);
            let codec = Sq8Codec::train(rows.iter().map(|r| r.as_slice()), d);
            let pq = codec.prepare_query(&q);
            let mut qhat = Vec::new();
            codec.decode_into(&pq.codes, &mut qhat);
            let mut codes = vec![0u8; d];
            let mut xhat = Vec::new();
            for (ri, row) in rows.iter().enumerate() {
                let (corr, norm) = codec.encode_into(row, &mut codes);
                let got = codec.score_codes(metric, &pq, &codes, corr, norm);
                codec.decode_into(&codes, &mut xhat);
                // (a) identical to scoring the decoded vectors exactly,
                // up to f32 rounding of the factored algebra.
                let want = metric.score(&qhat, &xhat);
                let tol = 2e-3 * (1.0 + want.abs());
                if (got - want).abs() > tol {
                    return Err(format!(
                        "{metric} row {ri} d={d}: quant {got} vs dequantized-scalar {want}"
                    ));
                }
                // (b) within the codec's error bound of the exact score,
                // computed from the actual reconstruction errors.
                let exact = metric.score(&q, row);
                let bound = score_error_bound(metric, &codec, &q, &qhat, row, &xhat);
                if (got - exact).abs() > bound {
                    return Err(format!(
                        "{metric} row {ri} d={d}: quant {got} vs exact {exact} \
                         beyond codec bound {bound}"
                    ));
                }
            }
            Ok(())
        });
    }

    /// Instance-wise error bound for a quantized score: propagate the
    /// actual per-vector reconstruction errors through each metric's
    /// algebra, plus float-rounding slack.
    fn score_error_bound(
        metric: Metric,
        codec: &Sq8Codec,
        q: &[f32],
        qhat: &[f32],
        x: &[f32],
        xhat: &[f32],
    ) -> f32 {
        let l2 = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(u, v)| (u - v) * (u - v)).sum::<f32>().sqrt()
        };
        let nrm = |a: &[f32]| -> f32 { a.iter().map(|v| v * v).sum::<f32>().sqrt() };
        let eq = l2(q, qhat);
        let ex = l2(x, xhat);
        let slack = 1e-3 * (1.0 + nrm(q) * nrm(x)) + 1e-4;
        match metric {
            Metric::L2 => {
                // | ||q-x||² - ||q̂-x̂||² | <= (e_q+e_x)·(||q-x|| + ||q̂-x̂||).
                let del = eq + ex;
                del * (l2(q, x) + l2(qhat, xhat)) + slack
            }
            Metric::Ip => {
                // |q·x - q̂·x̂| <= e_x·||q|| + e_q·||x̂||.
                ex * nrm(q) + eq * nrm(xhat) + slack
            }
            Metric::Angular => {
                // Cosine is bounded by the angle perturbations of each
                // side: |Δcos| <= e_q/||q|| + e_x/||x|| (+ guard slack).
                let (nq, nx) = (nrm(q), nrm(x));
                if nq <= 1e-6 || nx <= 1e-6 {
                    2.0 + slack // degenerate: cosine guard returns 0
                } else {
                    2.0 * (eq / nq + ex / nx) + slack
                }
            }
        }
    }

    #[test]
    fn score_ids_block_matches_scalar_score_codes() {
        let data = SyntheticSpec::deep_like(200, 24, 9).generate();
        let plane = QuantPlane::encode_dataset(&data, 0);
        let view = plane.view();
        let q = plane.codec().prepare_query(data.get(7));
        let ids: Vec<u32> = (0..200).step_by(3).collect();
        for metric in [Metric::L2, Metric::Angular, Metric::Ip] {
            let mut block = Vec::new();
            view.score_ids(metric, &q, &ids, &mut block);
            assert_eq!(block.len(), ids.len());
            for (j, &v) in ids.iter().enumerate() {
                let want = view.score(metric, &q, v as usize);
                assert_eq!(
                    block[j].to_bits(),
                    want.to_bits(),
                    "{metric} id {v}: block path diverges from scalar"
                );
            }
        }
    }

    #[test]
    fn plane_layout_aligned_and_4x_smaller() {
        let d = 96usize;
        let data = SyntheticSpec::deep_like(512, d, 5).generate();
        let plane = QuantPlane::encode_dataset(&data, 0);
        // Base pointer and every row 32-byte aligned; stride padded.
        assert_eq!(plane.codes().as_ptr() as usize % 32, 0);
        assert_eq!(plane.stride() % CODE_ALIGN, 0);
        assert_eq!(plane.stride(), 96);
        for i in [0usize, 1, 17, 511] {
            assert_eq!(plane.view().row(i).as_ptr() as usize % 32, 0, "row {i} misaligned");
        }
        // Acceptance: the code plane is ~4x smaller than the f32 rows.
        let f32_bytes = data.len() * d * 4;
        let ratio = f32_bytes as f64 / plane.bytes() as f64;
        assert!(ratio >= 3.0, "plane only {ratio:.2}x smaller ({} bytes)", plane.bytes());
    }

    #[test]
    fn refine_budget_clamps() {
        let data = SyntheticSpec::deep_like(32, 8, 3).generate();
        let auto = QuantPlane::encode_dataset(&data, 0);
        assert_eq!(auto.refine_for(10), 40);
        let fixed = QuantPlane::encode_dataset(&data, 64);
        assert_eq!(fixed.refine_for(10), 64);
        assert_eq!(fixed.refine_for(100), 100, "refine_k must never drop below k");
    }

    #[test]
    fn exact_top1_survives_quantized_scoring() {
        // Self-queries: the quantized tier must rank each row's own code
        // first (or tie) among all rows for L2 — a coarse sanity check
        // that the algebra is wired right end to end.
        let data = SyntheticSpec::deep_like(300, 16, 31).generate();
        let plane = QuantPlane::encode_dataset(&data, 0);
        let view = plane.view();
        let ids: Vec<u32> = (0..data.len() as u32).collect();
        let mut scores = Vec::new();
        for probe in [0usize, 42, 299] {
            let q = plane.codec().prepare_query(data.get(probe));
            view.score_ids(Metric::L2, &q, &ids, &mut scores);
            let best = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            assert_eq!(
                scores[best].to_bits(),
                scores[probe].to_bits(),
                "probe {probe}: own code not maximal"
            );
        }
    }
}
