//! Load monitor: the per-partition time-series store the driver samples
//! into and the elasticity controller reads from.
//!
//! One [`Monitor`] owns, per partition: a QPS series
//! ([`crate::stats::ThroughputSeries`]), a latency histogram
//! ([`crate::obs::Histogram`] — the registry's log buckets, so a monitor
//! "p99" is the same estimator as a scrape's `_p99`, ±4.4%), a
//! queue-depth gauge and a replica-count gauge (both
//! [`crate::stats::GaugeSeries`]). Plus run-wide counters, the minimum
//! observed coverage, and a timestamped event log (scale-ups, reroutes).
//! Methods take `&mut self`; the driver serializes access behind one
//! `Mutex`, which is also the natural consistency boundary for the
//! controller's read-decide-act tick. [`Monitor::scrape_into`] re-exports
//! the headline numbers as a registry scrape source while a drill runs.
//!
//! [`Monitor::to_json`] exports everything through
//! [`crate::util::json::Json`] for bench trending (`load/*` keys) and
//! offline plotting.

use std::time::{Duration, Instant};

use crate::obs::Histogram;
use crate::stats::{GaugeSeries, ThroughputSeries};
use crate::types::PartitionId;
use crate::util::json::Json;

/// Per-partition slice of the monitor.
struct PartitionStats {
    qps: ThroughputSeries,
    /// Query latencies attributed to this partition, microseconds
    /// (registry log buckets — constant memory under any load).
    latencies: Histogram,
    depth: GaugeSeries,
    replicas: GaugeSeries,
    /// Most recent depth sample — what the controller's tick reads.
    last_depth: f64,
    /// Most recent replica-count sample.
    last_replicas: f64,
}

impl PartitionStats {
    fn new(window: Duration) -> Self {
        PartitionStats {
            qps: ThroughputSeries::new(window),
            latencies: Histogram::new(),
            depth: GaugeSeries::new(window),
            replicas: GaugeSeries::new(window),
            last_depth: 0.0,
            last_replicas: 0.0,
        }
    }
}

/// Run-wide and per-partition observability for one trace replay.
pub struct Monitor {
    start: Instant,
    parts: Vec<PartitionStats>,
    qps: ThroughputSeries,
    all_latencies: Histogram,
    pub queries: u64,
    pub inserts: u64,
    pub deletes: u64,
    pub errors: u64,
    min_coverage: f64,
    /// Timestamped controller/driver events: (ms since start, message).
    events: Vec<(f64, String)>,
}

impl Monitor {
    /// A monitor over `partitions` partitions, bucketing series at
    /// `window` granularity, with time zero at `start`.
    pub fn new(partitions: usize, window: Duration, start: Instant) -> Self {
        Monitor {
            start,
            parts: (0..partitions).map(|_| PartitionStats::new(window)).collect(),
            qps: ThroughputSeries::new(window),
            all_latencies: Histogram::new(),
            queries: 0,
            inserts: 0,
            deletes: 0,
            errors: 0,
            min_coverage: 1.0,
            events: Vec::new(),
        }
    }

    fn ms_since_start(&self, at: Instant) -> f64 {
        at.saturating_duration_since(self.start).as_secs_f64() * 1_000.0
    }

    /// Record one answered query: attributed to its primary (first
    /// routed) partition, with the open-loop latency (measured from the
    /// *scheduled* arrival) and its coverage report.
    pub fn record_query(
        &mut self,
        at: Instant,
        primary: PartitionId,
        latency_us: f64,
        coverage: f64,
    ) {
        self.queries += 1;
        self.qps.record(at);
        self.all_latencies.observe(latency_us);
        if coverage < self.min_coverage {
            self.min_coverage = coverage;
        }
        if let Some(p) = self.parts.get_mut(primary as usize) {
            p.qps.record(at);
            p.latencies.observe(latency_us);
        }
    }

    /// Record one accepted write.
    pub fn record_write(&mut self, at: Instant, delete: bool) {
        self.qps.record(at);
        if delete {
            self.deletes += 1;
        } else {
            self.inserts += 1;
        }
    }

    /// Record a failed operation (rejected/timed-out).
    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    /// Sample a partition's broker queue depth.
    pub fn sample_depth(&mut self, at: Instant, partition: PartitionId, depth: f64) {
        if let Some(p) = self.parts.get_mut(partition as usize) {
            p.depth.observe(at, depth);
            p.last_depth = depth;
        }
    }

    /// Sample a partition's live replica count.
    pub fn sample_replicas(&mut self, at: Instant, partition: PartitionId, n: f64) {
        if let Some(p) = self.parts.get_mut(partition as usize) {
            p.replicas.observe(at, n);
            p.last_replicas = n;
        }
    }

    /// The most recent queue-depth sample for a partition (0.0 before
    /// the first sample) — the controller's primary pressure signal.
    pub fn last_depth(&self, partition: PartitionId) -> f64 {
        self.parts.get(partition as usize).map(|p| p.last_depth).unwrap_or(0.0)
    }

    /// The most recent replica-count sample for a partition.
    pub fn last_replicas(&self, partition: PartitionId) -> f64 {
        self.parts.get(partition as usize).map(|p| p.last_replicas).unwrap_or(0.0)
    }

    /// Append a timestamped event (controller action, driver milestone).
    pub fn note_event(&mut self, at: Instant, msg: impl Into<String>) {
        let t = self.ms_since_start(at);
        self.events.push((t, msg.into()));
    }

    /// Overall latency percentile (microseconds, registry bucket
    /// estimate); NaN before any query.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.all_latencies.quantile(p / 100.0).unwrap_or(f64::NAN)
    }

    /// Latency percentile for one partition's queries; NaN if none.
    pub fn partition_latency_percentile(&self, partition: PartitionId, p: f64) -> f64 {
        self.parts
            .get(partition as usize)
            .and_then(|s| s.latencies.quantile(p / 100.0))
            .unwrap_or(f64::NAN)
    }

    /// Queries attributed to a partition.
    pub fn partition_queries(&self, partition: PartitionId) -> u64 {
        self.parts.get(partition as usize).map(|p| p.qps.total()).unwrap_or(0)
    }

    /// Minimum coverage observed across every answered query.
    pub fn min_coverage(&self) -> f64 {
        self.min_coverage
    }

    pub fn events(&self) -> &[(f64, String)] {
        &self.events
    }

    /// Push the monitor's headline numbers into a registry scrape — the
    /// load surface the driver registers with
    /// [`crate::obs::MetricsRegistry::register_source`] for the duration
    /// of a drill, so `SimCluster::observe()` sees the open-loop view
    /// (driver-side latency, errors, controller pressure signals) next to
    /// the serving-side counters.
    pub fn scrape_into(&self, out: &mut Vec<(String, f64)>) {
        out.push(("load_queries_total".into(), self.queries as f64));
        out.push(("load_inserts_total".into(), self.inserts as f64));
        out.push(("load_deletes_total".into(), self.deletes as f64));
        out.push(("load_errors_total".into(), self.errors as f64));
        out.push(("load_min_coverage".into(), self.min_coverage));
        if let Some(p) = self.all_latencies.quantile(0.50) {
            out.push(("load_latency_p50_us".into(), p));
        }
        if let Some(p) = self.all_latencies.quantile(0.99) {
            out.push(("load_latency_p99_us".into(), p));
        }
        for (i, p) in self.parts.iter().enumerate() {
            out.push((format!("load_queue_depth{{partition=\"{i}\"}}"), p.last_depth));
            out.push((format!("load_replicas{{partition=\"{i}\"}}"), p.last_replicas));
        }
    }

    /// Export the full run as JSON: counters, overall quantiles, the
    /// event log, and per-partition series (QPS, mean/max queue depth,
    /// replica count) — the bench-trending payload.
    pub fn to_json(&self) -> Json {
        let series = |s: &[(f64, f64)]| {
            Json::Arr(
                s.iter()
                    .map(|&(t, v)| Json::Arr(vec![Json::num(t), Json::num(v)]))
                    .collect(),
            )
        };
        let parts: Vec<Json> = self
            .parts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                Json::obj(vec![
                    ("partition", Json::num(i as f64)),
                    ("queries", Json::num(p.qps.total() as f64)),
                    ("p50_us", Json::num(nan_to_null(p.latencies.quantile(0.50).unwrap_or(f64::NAN)))),
                    ("p99_us", Json::num(nan_to_null(p.latencies.quantile(0.99).unwrap_or(f64::NAN)))),
                    ("qps_series", series(&p.qps.series())),
                    ("depth_mean_series", series(&p.depth.series())),
                    ("depth_max_series", series(&p.depth.max_series())),
                    ("replica_series", series(&p.replicas.series())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("queries", Json::num(self.queries as f64)),
            ("inserts", Json::num(self.inserts as f64)),
            ("deletes", Json::num(self.deletes as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("min_coverage", Json::num(self.min_coverage)),
            ("p50_us", Json::num(nan_to_null(self.latency_percentile(50.0)))),
            ("p99_us", Json::num(nan_to_null(self.latency_percentile(99.0)))),
            ("qps_series", series(&self.qps.series())),
            ("partitions", Json::Arr(parts)),
            (
                "events",
                Json::Arr(
                    self.events
                        .iter()
                        .map(|(t, m)| Json::Arr(vec![Json::num(*t), Json::str(m.clone())]))
                        .collect(),
                ),
            ),
        ])
    }
}

/// JSON has no NaN; an empty-sample quantile serializes as -1.
fn nan_to_null(v: f64) -> f64 {
    if v.is_nan() {
        -1.0
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attribution_counters_and_coverage_floor() {
        let t0 = Instant::now();
        let mut m = Monitor::new(2, Duration::from_millis(100), t0);
        m.record_query(t0 + Duration::from_millis(10), 0, 500.0, 1.0);
        m.record_query(t0 + Duration::from_millis(20), 0, 1_500.0, 1.0);
        m.record_query(t0 + Duration::from_millis(30), 1, 100.0, 0.5);
        m.record_write(t0 + Duration::from_millis(40), false);
        m.record_error();
        assert_eq!(m.queries, 3);
        assert_eq!(m.inserts, 1);
        assert_eq!(m.errors, 1);
        assert_eq!(m.partition_queries(0), 2);
        assert_eq!(m.partition_queries(1), 1);
        assert!((m.min_coverage() - 0.5).abs() < 1e-12);
        assert!(m.partition_latency_percentile(0, 99.0) >= 500.0);
        // Out-of-range partition ids are ignored, not a panic.
        m.record_query(t0, 99, 1.0, 1.0);
        assert_eq!(m.partition_queries(99), 0);
    }

    #[test]
    fn depth_samples_feed_last_depth_and_series() {
        let t0 = Instant::now();
        let mut m = Monitor::new(1, Duration::from_millis(50), t0);
        assert_eq!(m.last_depth(0), 0.0);
        m.sample_depth(t0 + Duration::from_millis(10), 0, 4.0);
        m.sample_depth(t0 + Duration::from_millis(20), 0, 8.0);
        m.sample_replicas(t0 + Duration::from_millis(20), 0, 2.0);
        assert_eq!(m.last_depth(0), 8.0);
        assert_eq!(m.last_replicas(0), 2.0);
    }

    #[test]
    fn scrape_into_exports_headline_keys() {
        let t0 = Instant::now();
        let mut m = Monitor::new(2, Duration::from_millis(100), t0);
        m.record_query(t0 + Duration::from_millis(5), 1, 750.0, 1.0);
        m.sample_depth(t0 + Duration::from_millis(6), 1, 3.0);
        let mut out = Vec::new();
        m.scrape_into(&mut out);
        let get = |k: &str| out.iter().find(|(n, _)| n == k).map(|(_, v)| *v);
        assert_eq!(get("load_queries_total"), Some(1.0));
        assert_eq!(get("load_errors_total"), Some(0.0));
        let p99 = get("load_latency_p99_us").expect("p99 after one query");
        assert!((700.0..800.0).contains(&p99), "bucketed p99={p99} of a 750µs sample");
        assert_eq!(get("load_queue_depth{partition=\"1\"}"), Some(3.0));
        assert_eq!(get("load_queue_depth{partition=\"0\"}"), Some(0.0));
    }

    #[test]
    fn json_export_parses_and_carries_keys() {
        let t0 = Instant::now();
        let mut m = Monitor::new(2, Duration::from_millis(100), t0);
        m.record_query(t0 + Duration::from_millis(5), 1, 750.0, 1.0);
        m.note_event(t0 + Duration::from_millis(6), "scale-up p1");
        let text = m.to_json().pretty();
        let back = Json::parse(&text).expect("monitor JSON must parse");
        assert_eq!(back.get("queries").and_then(Json::as_usize), Some(1));
        assert_eq!(back.get("partitions").and_then(Json::as_arr).map(|a| a.len()), Some(2));
        assert_eq!(back.get("events").and_then(Json::as_arr).map(|a| a.len()), Some(1));
        // Empty-partition quantiles export as -1, never NaN.
        let p0 = &back.get("partitions").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(p0.get("p99_us").and_then(Json::as_f64), Some(-1.0));
    }
}
