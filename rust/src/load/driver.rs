//! Trace driver: replay a [`TraceSpec`] against a running
//! [`SimCluster`] at its scheduled (open-loop) arrival times.
//!
//! The driver pre-generates everything the spec determines — arrival
//! times, op kinds, partition targets, query/insert vectors — before
//! touching the cluster, so runtime outcomes can never skew the
//! workload (same discipline as [`crate::chaos::runner`]). A dispatcher
//! thread releases each job at its scheduled offset to a pool of
//! client workers; on every `tick_ms` boundary it samples per-partition
//! queue depth and replica count into the [`Monitor`] and, when
//! configured, runs one [`ElasticityController`] policy iteration.
//!
//! **Latency is charged from the scheduled arrival**, not from when a
//! client thread picked the job up — client-side queueing counts
//! against the system (no coordinated omission), which is what makes
//! the static-vs-elastic p99 comparison honest under overload.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::SimCluster;
use crate::config::QueryParams;
use crate::error::{PyramidError, Result};
use crate::meta::{PyramidIndex, Router};
use crate::types::{PartitionId, VectorId};
use crate::util::rng::Rng;

use super::controller::{ControllerConfig, ElasticityController};
use super::trace::{OpKind, TraceSpec, MAX_EVENTS};
use super::Monitor;

/// Sub-seed for the query/insert vector pool.
const POOL_STREAM: u64 = 0x10AD_9001_10AD_9001;
/// Sub-seed for per-event partition targeting and pool picks.
const TARGET_STREAM: u64 = 0x10AD_7A26_10AD_7A26;

/// How the driver replays a trace.
#[derive(Debug, Clone, Copy)]
pub struct LoadConfig {
    /// Client worker threads issuing requests.
    pub clients: usize,
    /// Sampling / controller cadence, milliseconds.
    pub tick_ms: u64,
    /// Query parameters for every trace query.
    pub params: QueryParams,
    /// Elasticity policy; None replays against the static placement
    /// (bit-identical legacy routing, no scaling).
    pub controller: Option<ControllerConfig>,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            clients: 16,
            tick_ms: 25,
            params: QueryParams::default(),
            controller: None,
        }
    }
}

/// Outcome of one trace replay.
#[derive(Debug)]
pub struct LoadReport {
    pub spec: TraceSpec,
    pub queries: u64,
    pub inserts: u64,
    pub deletes: u64,
    pub errors: u64,
    /// Wall clock of the whole replay, milliseconds.
    pub wall_ms: f64,
    /// Answered queries per second of wall clock.
    pub qps: f64,
    /// Open-loop latency quantiles, microseconds (NaN with no queries).
    pub p50_us: f64,
    pub p99_us: f64,
    /// The trace's hot partition (explicit `hot=` or top Zipf rank).
    pub hot_partition: Option<PartitionId>,
    /// Hot partition's p99 / query count (NaN / 0 without one).
    pub hot_p99_us: f64,
    pub hot_queries: u64,
    /// Minimum coverage across every answered query.
    pub min_coverage: f64,
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// First overload tick → first scale-up, milliseconds.
    pub reaction_ms: Option<f64>,
    /// Timestamped controller/driver events.
    pub events: Vec<(f64, String)>,
    /// Full monitor export (pretty JSON) for trending/plotting.
    pub json: String,
}

/// One pre-generated trace event.
struct Job {
    at_ms: f64,
    op: OpKind,
    /// Primary partition this event targets (attribution key).
    partition: PartitionId,
    vector: Arc<Vec<f32>>,
}

/// Replay `spec` against `cluster`. The index is only used for its
/// router (partition targeting) and dimensionality — the cluster serves
/// every request.
pub fn run_trace(
    cluster: &SimCluster,
    index: &PyramidIndex,
    spec: &TraceSpec,
    cfg: &LoadConfig,
) -> Result<LoadReport> {
    spec.validate()?;
    let router = Router::from_index(index);
    let partitions = router.partitions();
    let dim = router.dim().ok_or_else(|| {
        PyramidError::Config("load driver needs a routed (non-broadcast) cluster".into())
    })?;

    // --- pre-generate the workload (seeded; no cluster interaction) ---
    let pools = build_pools(spec, &router, partitions, dim);
    let arrivals = spec.arrivals();
    let truncated = arrivals.len() >= MAX_EVENTS;
    let ops = spec.ops(arrivals.len());
    let weights = spec.partition_weights(partitions);
    let mut target_rng = Rng::seed_from_u64(spec.seed ^ TARGET_STREAM);
    let jobs: Vec<Job> = arrivals
        .iter()
        .zip(&ops)
        .map(|(&at_ms, &op)| {
            let p = target_rng.weighted(&weights) as PartitionId;
            let pool = &pools[p as usize];
            let vector = pool[target_rng.below(pool.len())].clone();
            match op {
                OpKind::Query => Job { at_ms, op, partition: p, vector },
                // Writes go onto a far shelf so they never perturb
                // query answers (same convention as the chaos runner).
                _ => {
                    let shifted: Vec<f32> = vector.iter().map(|v| v + 5.0).collect();
                    Job { at_ms, op, partition: p, vector: Arc::new(shifted) }
                }
            }
        })
        .collect();
    let total_jobs = jobs.len();

    // --- replay ---
    let run_start = Instant::now();
    let window = Duration::from_millis(cfg.tick_ms.max(1) * 4);
    let monitor = Arc::new(Mutex::new(Monitor::new(partitions, window, run_start)));
    // While the drill runs, the monitor is a live scrape source: a
    // concurrent `cluster.observe()` sees the driver-side view (open-loop
    // latency, errors, pressure signals) next to the serving counters.
    if let Some(o) = cluster.obs() {
        let m = monitor.clone();
        o.registry.register_source(
            "load_monitor",
            Box::new(move |out| m.lock().unwrap().scrape_into(out)),
        );
    }
    if truncated {
        monitor
            .lock()
            .unwrap()
            .note_event(run_start, format!("trace truncated at {MAX_EVENTS} events"));
    }
    let mut controller = cfg
        .controller
        .map(|c| ElasticityController::new(c, cluster, partitions));
    let inserted: Mutex<Vec<VectorId>> = Mutex::new(Vec::new());
    let done = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<Job>();
    let rx = Arc::new(Mutex::new(rx));
    let params = cfg.params;

    std::thread::scope(|s| {
        for _ in 0..cfg.clients.max(1) {
            let rx = rx.clone();
            let monitor = &monitor;
            let inserted = &inserted;
            let done = &done;
            s.spawn(move || loop {
                let job = { rx.lock().unwrap().recv() };
                let Ok(job) = job else { break };
                run_job(cluster, &params, run_start, &job, monitor, inserted);
                done.fetch_add(1, Ordering::Relaxed);
            });
        }

        // Dispatcher (this thread): release jobs on schedule, tick the
        // sampler/controller between releases and while draining.
        let tick = Duration::from_millis(cfg.tick_ms.max(1));
        let mut next_tick = run_start + tick;
        let mut do_tick = |at: Instant| {
            let now_ms = at.saturating_duration_since(run_start).as_secs_f64() * 1_000.0;
            let mut m = monitor.lock().unwrap();
            for p in 0..partitions {
                let pid = p as PartitionId;
                m.sample_depth(at, pid, cluster.queue_depth(pid) as f64);
                m.sample_replicas(at, pid, cluster.executors_for_partition(pid).len() as f64);
            }
            if let Some(c) = controller.as_mut() {
                c.tick(now_ms, at, cluster, &mut m);
            }
        };
        for job in jobs {
            let due = run_start + Duration::from_secs_f64(job.at_ms / 1_000.0);
            while next_tick < due {
                sleep_until(next_tick);
                do_tick(next_tick);
                next_tick += tick;
            }
            sleep_until(due);
            if tx.send(job).is_err() {
                break;
            }
        }
        drop(tx);
        // Drain: keep sampling until every job is answered (bounded —
        // an overloaded static run finishes late but finite; the
        // coordinator deadline caps each straggler).
        let drain_deadline = Instant::now() + Duration::from_secs(30);
        while done.load(Ordering::Relaxed) < total_jobs && Instant::now() < drain_deadline {
            sleep_until(next_tick);
            do_tick(next_tick);
            next_tick += tick;
        }
    });

    let wall_ms = run_start.elapsed().as_secs_f64() * 1_000.0;
    // Drop the scrape source before unwrapping the monitor: unregister
    // frees the registry's Arc clone, leaving ours as the last one.
    if let Some(o) = cluster.obs() {
        o.registry.unregister_source("load_monitor");
    }
    let m = Arc::try_unwrap(monitor)
        .unwrap_or_else(|_| unreachable!("load_monitor source unregistered above"))
        .into_inner()
        .unwrap();
    let hot = spec.hot_for(partitions);
    Ok(LoadReport {
        spec: *spec,
        queries: m.queries,
        inserts: m.inserts,
        deletes: m.deletes,
        errors: m.errors,
        wall_ms,
        qps: m.queries as f64 / (wall_ms / 1_000.0).max(1e-9),
        p50_us: m.latency_percentile(50.0),
        p99_us: m.latency_percentile(99.0),
        hot_partition: hot,
        hot_p99_us: hot.map(|p| m.partition_latency_percentile(p, 99.0)).unwrap_or(f64::NAN),
        hot_queries: hot.map(|p| m.partition_queries(p)).unwrap_or(0),
        min_coverage: m.min_coverage(),
        scale_ups: controller.as_ref().map(|c| c.scale_ups).unwrap_or(0),
        scale_downs: controller.as_ref().map(|c| c.scale_downs).unwrap_or(0),
        reaction_ms: controller.as_ref().and_then(|c| c.reaction_ms()),
        events: m.events().to_vec(),
        json: m.to_json().pretty(),
    })
}

/// Execute one job against the cluster and record the outcome.
fn run_job(
    cluster: &SimCluster,
    params: &QueryParams,
    run_start: Instant,
    job: &Job,
    monitor: &Mutex<Monitor>,
    inserted: &Mutex<Vec<VectorId>>,
) {
    match job.op {
        OpKind::Query => {
            let r = cluster.execute_detailed(&job.vector, params);
            let now = Instant::now();
            let lat_us = (now.saturating_duration_since(run_start).as_secs_f64() * 1e6
                - job.at_ms * 1e3)
                .max(0.0);
            let mut m = monitor.lock().unwrap();
            match r {
                Ok(qr) => m.record_query(now, job.partition, lat_us, qr.coverage()),
                Err(_) => m.record_error(),
            }
        }
        OpKind::Insert => match cluster.insert(&job.vector) {
            Ok(id) => {
                inserted.lock().unwrap().push(id);
                monitor.lock().unwrap().record_write(Instant::now(), false);
            }
            Err(_) => monitor.lock().unwrap().record_error(),
        },
        OpKind::Delete => {
            // Delete the most recent surviving insert; a delete with
            // nothing inserted yet is a no-op, not an error.
            let id = inserted.lock().unwrap().pop();
            if let Some(id) = id {
                match cluster.delete(id) {
                    Ok(()) => monitor.lock().unwrap().record_write(Instant::now(), true),
                    Err(_) => monitor.lock().unwrap().record_error(),
                }
            }
        }
    }
}

/// Build per-partition query pools: seeded unit-cube candidates routed
/// through the meta-HNSW (branch 1) and bucketed by their primary
/// partition, so "target partition p" means "a query that genuinely
/// routes to p". Partitions the sampler never hits fall back to the
/// global pool (they cannot be hot-spotted, but stay queryable).
fn build_pools(
    spec: &TraceSpec,
    router: &Router,
    partitions: usize,
    dim: usize,
) -> Vec<Vec<Arc<Vec<f32>>>> {
    let mut rng = Rng::seed_from_u64(spec.seed ^ POOL_STREAM);
    let mut pools: Vec<Vec<Arc<Vec<f32>>>> = vec![Vec::new(); partitions];
    let mut all: Vec<Arc<Vec<f32>>> = Vec::new();
    const PER_PARTITION: usize = 32;
    const MAX_TRIES: usize = 4_096;
    for _ in 0..MAX_TRIES {
        let v: Vec<f32> = (0..dim).map(|_| rng.f32_range(0.0, 1.0)).collect();
        let prepared = router.prepare_query(&v);
        let routed = router.route(&prepared, 1, 64);
        let v = Arc::new(v);
        all.push(v.clone());
        if let Some(&p) = routed.first() {
            let pool = &mut pools[p as usize];
            if pool.len() < PER_PARTITION {
                pool.push(v);
            }
        }
        if pools.iter().all(|p| p.len() >= PER_PARTITION) {
            break;
        }
    }
    for pool in pools.iter_mut() {
        if pool.is_empty() {
            pool.extend(all.iter().take(PER_PARTITION).cloned());
        }
    }
    pools
}

fn sleep_until(t: Instant) {
    let now = Instant::now();
    if t > now {
        std::thread::sleep(t - now);
    }
}
