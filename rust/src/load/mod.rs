//! Trace-driven load harness with closed-loop elasticity
//! (EXPERIMENTS.md §10).
//!
//! The paper's headline claims are throughput under heavy traffic; this
//! module supplies the missing scenario engine. Three pieces:
//!
//! * [`TraceSpec`] — a seeded, one-line workload grammar (open-loop
//!   constant/Poisson/diurnal/burst arrivals, Zipf-skewed and
//!   hot-spotted partition targeting, mixed query/insert/delete), with
//!   `parse`/`Display` round-tripping exactly like the chaos schedule
//!   grammar.
//! * [`run_trace`] — the driver: replays a trace against a
//!   [`SimCluster`](crate::cluster::SimCluster) at the scheduled
//!   arrival times, charging latency from the *scheduled* arrival (no
//!   coordinated omission), while sampling per-partition QPS, latency
//!   quantiles, queue depth and replica count into a [`Monitor`].
//! * [`ElasticityController`] — the closed loop: a hysteresis policy
//!   over the monitor's queue-depth signal that scales hot partitions'
//!   replica sets ([`SimCluster::scale_partition`](crate::cluster::SimCluster::scale_partition))
//!   and steers their traffic to the shortest live replica queue
//!   ([`SimCluster::set_route_weight`](crate::cluster::SimCluster::set_route_weight)).
//!
//! Invariant: with [`LoadConfig::controller`] set to `None`, a replay
//! exercises exactly the pre-elasticity serving path — no routing
//! weights, no scaling, bit-identical fan-out (pinned by
//! `rust/tests/load.rs`).

mod controller;
mod driver;
mod monitor;
mod trace;

pub use controller::{ControllerConfig, ElasticityController};
pub use driver::{run_trace, LoadConfig, LoadReport};
pub use monitor::Monitor;
pub use trace::{Arrival, OpKind, TraceSpec, MAX_EVENTS};
