//! Trace grammar (EXPERIMENTS.md §10): seeded open-loop workload specs.
//!
//! A trace is one line of whitespace-separated `key=value` pairs — the
//! same diffable, greppable convention as the chaos schedule grammar
//! ([`crate::chaos::schedule::ChaosSpec`]):
//!
//! ```text
//! seed=7 duration_ms=2000 rate=500 arrival=poisson \
//!     period_ms=1000 amp=0.6 burst_every_ms=500 burst_len_ms=100 burst_x=8 \
//!     zipf=0 hot=-1 hot_frac=0 query=1 insert=0 delete=0
//! ```
//!
//! Every key has a default, so `seed=7` alone is a valid trace; unknown
//! keys are an error. The seed drives *every* derived stream — arrival
//! times, op kinds, partition targets — through distinct XOR'd
//! sub-seeds, so one u64 reproduces the whole workload and no stream
//! can alias another.
//!
//! Arrivals are **open-loop**: the event times are fixed up front by the
//! spec, never by how fast the system answered (the driver measures
//! latency from the *scheduled* arrival, so client-side queueing is
//! charged to the system — no coordinated omission).

use crate::error::{PyramidError, Result};
use crate::types::PartitionId;
use crate::util::rng::Rng;

/// Sub-seed for the arrival-time stream (distinct from every other
/// stream so they never alias; see [`crate::chaos::runner`] for the
/// same convention on the chaos side).
const ARRIVAL_STREAM: u64 = 0x10AD_A221_10AD_A221;
/// Sub-seed for the op-kind (query/insert/delete) stream.
const OP_STREAM: u64 = 0x10AD_0050_10AD_0050;
/// Sub-seed for the Zipf rank permutation over partitions.
const RANK_STREAM: u64 = 0x10AD_2A2A_10AD_2A2A;

/// Arrival process shape: how event times are laid out over the trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// Evenly spaced at exactly `rate` events/sec.
    Constant,
    /// Homogeneous Poisson at `rate` events/sec.
    Poisson,
    /// Non-homogeneous Poisson, rate modulated by a sinusoid:
    /// `rate * (1 + amp * sin(2π t / period_ms))` — a compressed
    /// day/night cycle.
    Diurnal,
    /// Poisson at `rate`, multiplied by `burst_x` inside periodic burst
    /// windows (`burst_len_ms` out of every `burst_every_ms`).
    Burst,
}

impl Arrival {
    fn key(self) -> &'static str {
        match self {
            Arrival::Constant => "constant",
            Arrival::Poisson => "poisson",
            Arrival::Diurnal => "diurnal",
            Arrival::Burst => "burst",
        }
    }

    fn parse(s: &str) -> Option<Arrival> {
        match s {
            "constant" => Some(Arrival::Constant),
            "poisson" => Some(Arrival::Poisson),
            "diurnal" => Some(Arrival::Diurnal),
            "burst" => Some(Arrival::Burst),
            _ => None,
        }
    }
}

/// What one trace event does to the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Query,
    Insert,
    Delete,
}

/// A complete, self-contained workload trace. One seed reproduces the
/// entire event stream; `parse` is the exact inverse of `Display`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceSpec {
    pub seed: u64,
    /// Trace length, milliseconds of wall clock.
    pub duration_ms: u64,
    /// Mean event rate, events per second.
    pub rate: f64,
    pub arrival: Arrival,
    /// Sinusoid period for [`Arrival::Diurnal`], milliseconds.
    pub period_ms: u64,
    /// Sinusoid amplitude for [`Arrival::Diurnal`] (0..=1).
    pub amplitude: f64,
    /// Burst cadence for [`Arrival::Burst`], milliseconds.
    pub burst_every_ms: u64,
    /// Burst window length, milliseconds (≤ `burst_every_ms`).
    pub burst_len_ms: u64,
    /// Rate multiplier inside a burst window.
    pub burst_x: f64,
    /// Zipf skew exponent for partition popularity (0 = uniform).
    pub zipf: f64,
    /// Explicit hot partition (-1 = none; overrides nothing, *adds*
    /// `hot_frac` of traffic on top of the Zipf/uniform base).
    pub hot_partition: i64,
    /// Fraction of events redirected to the hot partition (0..=1).
    pub hot_frac: f64,
    /// Op mix weights (need not sum to 1; normalized at use).
    pub query_frac: f64,
    pub insert_frac: f64,
    pub delete_frac: f64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            seed: 1,
            duration_ms: 2_000,
            rate: 500.0,
            arrival: Arrival::Poisson,
            period_ms: 1_000,
            amplitude: 0.6,
            burst_every_ms: 500,
            burst_len_ms: 100,
            burst_x: 8.0,
            zipf: 0.0,
            hot_partition: -1,
            hot_frac: 0.0,
            query_frac: 1.0,
            insert_frac: 0.0,
            delete_frac: 0.0,
        }
    }
}

/// Hard cap on generated events, so a typo'd `rate=1e9` cannot OOM the
/// harness. Hitting it truncates the trace (the driver logs the cap).
pub const MAX_EVENTS: usize = 1_000_000;

impl TraceSpec {
    /// The default trace shape at a given seed.
    pub fn for_seed(seed: u64) -> Self {
        TraceSpec { seed, ..TraceSpec::default() }
    }

    /// Parse the `key=value` grammar. Inverse of [`std::fmt::Display`].
    pub fn parse(s: &str) -> Result<Self> {
        let mut spec = TraceSpec::default();
        for tok in s.split_whitespace() {
            let (key, val) = tok
                .split_once('=')
                .ok_or_else(|| PyramidError::Config(format!("trace: bad token {tok:?}")))?;
            // `|_| bad()` rather than a shared `|_| ...` closure: the
            // arms parse u64, i64 and f64, whose error types a single
            // closure parameter could not unify.
            let bad = || PyramidError::Config(format!("trace: bad value {tok:?}"));
            match key {
                "seed" => spec.seed = val.parse().map_err(|_| bad())?,
                "duration_ms" => spec.duration_ms = val.parse().map_err(|_| bad())?,
                "rate" => spec.rate = val.parse().map_err(|_| bad())?,
                "arrival" => {
                    spec.arrival = Arrival::parse(val).ok_or_else(|| {
                        PyramidError::Config(format!("trace: bad arrival {val:?}"))
                    })?
                }
                "period_ms" => spec.period_ms = val.parse().map_err(|_| bad())?,
                "amp" => spec.amplitude = val.parse().map_err(|_| bad())?,
                "burst_every_ms" => spec.burst_every_ms = val.parse().map_err(|_| bad())?,
                "burst_len_ms" => spec.burst_len_ms = val.parse().map_err(|_| bad())?,
                "burst_x" => spec.burst_x = val.parse().map_err(|_| bad())?,
                "zipf" => spec.zipf = val.parse().map_err(|_| bad())?,
                "hot" => spec.hot_partition = val.parse().map_err(|_| bad())?,
                "hot_frac" => spec.hot_frac = val.parse().map_err(|_| bad())?,
                "query" => spec.query_frac = val.parse().map_err(|_| bad())?,
                "insert" => spec.insert_frac = val.parse().map_err(|_| bad())?,
                "delete" => spec.delete_frac = val.parse().map_err(|_| bad())?,
                _ => return Err(PyramidError::Config(format!("trace: unknown key {key:?}"))),
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Cross-field sanity (also run by [`Self::parse`]).
    pub fn validate(&self) -> Result<()> {
        let err = |m: String| Err(PyramidError::Config(m));
        if self.rate <= 0.0 || !self.rate.is_finite() {
            return err(format!("trace: rate must be positive, got {}", self.rate));
        }
        if self.query_frac < 0.0 || self.insert_frac < 0.0 || self.delete_frac < 0.0 {
            return err("trace: op fractions must be non-negative".into());
        }
        if self.query_frac + self.insert_frac + self.delete_frac <= 0.0 {
            return err("trace: op fractions sum to zero — empty workload".into());
        }
        if !(0.0..=1.0).contains(&self.hot_frac) {
            return err(format!("trace: hot_frac must be in [0,1], got {}", self.hot_frac));
        }
        if self.period_ms == 0 || self.burst_every_ms == 0 {
            return err("trace: period_ms/burst_every_ms must be >= 1".into());
        }
        Ok(())
    }

    /// Instantaneous rate (events/sec) at offset `t_ms` into the trace.
    fn rate_at(&self, t_ms: f64) -> f64 {
        match self.arrival {
            Arrival::Constant | Arrival::Poisson => self.rate,
            Arrival::Diurnal => {
                let phase = std::f64::consts::TAU * t_ms / self.period_ms as f64;
                // Clamp so an amplitude > 1 can slow the night to a
                // trickle but never stop (or reverse) time.
                (self.rate * (1.0 + self.amplitude * phase.sin())).max(self.rate * 0.01)
            }
            Arrival::Burst => {
                let in_burst = (t_ms as u64) % self.burst_every_ms < self.burst_len_ms;
                if in_burst {
                    self.rate * self.burst_x
                } else {
                    self.rate
                }
            }
        }
    }

    /// The event arrival times, milliseconds from trace start, strictly
    /// increasing within `[0, duration_ms)`. Constant spacing for
    /// [`Arrival::Constant`]; otherwise a (non-)homogeneous Poisson
    /// process stepped at the instantaneous rate. Capped at
    /// [`MAX_EVENTS`].
    pub fn arrivals(&self) -> Vec<f64> {
        let mut out = Vec::new();
        let end = self.duration_ms as f64;
        match self.arrival {
            Arrival::Constant => {
                let step = 1_000.0 / self.rate;
                let mut t = 0.0;
                while t < end && out.len() < MAX_EVENTS {
                    out.push(t);
                    t += step;
                }
            }
            _ => {
                let mut rng = Rng::seed_from_u64(self.seed ^ ARRIVAL_STREAM);
                let mut t = 0.0;
                loop {
                    // Exponential gap at the rate in force *now* — a
                    // step-wise thinning-free approximation that is exact
                    // for Poisson and faithful at these modulation speeds.
                    let gap_ms = rng.exponential() / self.rate_at(t) * 1_000.0;
                    t += gap_ms;
                    if t >= end || out.len() >= MAX_EVENTS {
                        break;
                    }
                    out.push(t);
                }
            }
        }
        out
    }

    /// The op kind of each of `n` events (weighted by the op fractions,
    /// seeded independently of the arrival times).
    pub fn ops(&self, n: usize) -> Vec<OpKind> {
        let mut rng = Rng::seed_from_u64(self.seed ^ OP_STREAM);
        let w = [self.query_frac, self.insert_frac, self.delete_frac];
        (0..n)
            .map(|_| match rng.weighted(&w) {
                0 => OpKind::Query,
                1 => OpKind::Insert,
                _ => OpKind::Delete,
            })
            .collect()
    }

    /// Per-partition targeting weights (normalized to sum 1): Zipf
    /// `1/(rank+1)^zipf` over a seeded rank permutation, then `hot_frac`
    /// of the total mass moved onto the explicit hot partition (if set).
    /// `zipf=0, hot=-1` is exactly uniform.
    pub fn partition_weights(&self, partitions: usize) -> Vec<f64> {
        assert!(partitions > 0, "partition_weights needs >= 1 partition");
        let mut ranks: Vec<usize> = (0..partitions).collect();
        if self.zipf > 0.0 {
            // Which partition is popular is itself seeded, so two traces
            // at different seeds skew different partitions.
            Rng::seed_from_u64(self.seed ^ RANK_STREAM).shuffle(&mut ranks);
        }
        let mut w = vec![0.0f64; partitions];
        for (rank, &p) in ranks.iter().enumerate() {
            w[p] = 1.0 / ((rank + 1) as f64).powf(self.zipf);
        }
        let total: f64 = w.iter().sum();
        for x in w.iter_mut() {
            *x /= total;
        }
        if self.hot_frac > 0.0 && (0..partitions as i64).contains(&self.hot_partition) {
            for x in w.iter_mut() {
                *x *= 1.0 - self.hot_frac;
            }
            w[self.hot_partition as usize] += self.hot_frac;
        }
        w
    }

    /// The partition each trace treats as "the hot one" for reporting:
    /// the explicit `hot=` override, else the heaviest Zipf rank, else
    /// None (uniform trace).
    pub fn hot_for(&self, partitions: usize) -> Option<PartitionId> {
        if partitions == 0 {
            return None;
        }
        if self.hot_frac > 0.0 && (0..partitions as i64).contains(&self.hot_partition) {
            return Some(self.hot_partition as PartitionId);
        }
        if self.zipf > 0.0 {
            let w = self.partition_weights(partitions);
            let (p, _) = w
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            return Some(p as PartitionId);
        }
        None
    }
}

impl std::fmt::Display for TraceSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "seed={} duration_ms={} rate={} arrival={} period_ms={} amp={} \
             burst_every_ms={} burst_len_ms={} burst_x={} zipf={} hot={} hot_frac={} \
             query={} insert={} delete={}",
            self.seed,
            self.duration_ms,
            self.rate,
            self.arrival.key(),
            self.period_ms,
            self.amplitude,
            self.burst_every_ms,
            self.burst_len_ms,
            self.burst_x,
            self.zipf,
            self.hot_partition,
            self.hot_frac,
            self.query_frac,
            self.insert_frac,
            self.delete_frac,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_parse_roundtrip() {
        let spec = TraceSpec {
            seed: 1337,
            duration_ms: 750,
            rate: 123.5,
            arrival: Arrival::Diurnal,
            period_ms: 400,
            amplitude: 0.25,
            burst_every_ms: 300,
            burst_len_ms: 60,
            burst_x: 4.5,
            zipf: 1.1,
            hot_partition: 2,
            hot_frac: 0.75,
            query_frac: 0.8,
            insert_frac: 0.15,
            delete_frac: 0.05,
        };
        let line = spec.to_string();
        assert_eq!(TraceSpec::parse(&line).unwrap(), spec);
    }

    #[test]
    fn partial_line_fills_defaults() {
        let spec = TraceSpec::parse("seed=99").unwrap();
        assert_eq!(spec.seed, 99);
        assert_eq!(spec.rate, TraceSpec::default().rate);
        assert_eq!(spec.arrival, Arrival::Poisson);
    }

    #[test]
    fn unknown_key_and_bad_values_rejected() {
        assert!(TraceSpec::parse("seed=1 sneed=2").is_err());
        assert!(TraceSpec::parse("seed").is_err());
        assert!(TraceSpec::parse("rate=abc").is_err());
        assert!(TraceSpec::parse("arrival=weekly").is_err());
        assert!(TraceSpec::parse("rate=0").is_err());
        assert!(TraceSpec::parse("hot_frac=1.5").is_err());
        assert!(TraceSpec::parse("query=0 insert=0 delete=0").is_err());
    }

    #[test]
    fn arrivals_are_deterministic_monotone_and_bounded() {
        let spec = TraceSpec { duration_ms: 1_000, rate: 800.0, ..TraceSpec::for_seed(5) };
        let a = spec.arrivals();
        let b = spec.arrivals();
        assert_eq!(a, b, "same seed must reproduce the same arrival times");
        assert!(!a.is_empty());
        for w in a.windows(2) {
            assert!(w[0] < w[1], "arrival times must be strictly increasing");
        }
        assert!(*a.last().unwrap() < 1_000.0);
        // Poisson count concentrates near rate * duration.
        let expect = 800.0;
        assert!(
            (a.len() as f64) > expect * 0.7 && (a.len() as f64) < expect * 1.3,
            "got {} events, expected ~{expect}",
            a.len()
        );
    }

    #[test]
    fn constant_arrivals_are_evenly_spaced() {
        let spec = TraceSpec {
            arrival: Arrival::Constant,
            rate: 100.0,
            duration_ms: 500,
            ..TraceSpec::default()
        };
        let a = spec.arrivals();
        assert_eq!(a.len(), 50);
        for w in a.windows(2) {
            assert!((w[1] - w[0] - 10.0).abs() < 1e-9);
        }
    }

    #[test]
    fn burst_windows_are_denser_than_baseline() {
        let spec = TraceSpec {
            arrival: Arrival::Burst,
            rate: 200.0,
            burst_every_ms: 200,
            burst_len_ms: 50,
            burst_x: 10.0,
            duration_ms: 2_000,
            ..TraceSpec::for_seed(8)
        };
        let a = spec.arrivals();
        let in_burst =
            a.iter().filter(|&&t| (t as u64) % spec.burst_every_ms < spec.burst_len_ms).count();
        let outside = a.len() - in_burst;
        // Burst windows are 1/4 of the time but 10x the rate: the
        // per-ms density inside must dominate clearly.
        let d_in = in_burst as f64 / 500.0;
        let d_out = outside as f64 / 1_500.0;
        assert!(d_in > d_out * 4.0, "burst density {d_in} vs baseline {d_out}");
    }

    #[test]
    fn diurnal_peak_is_denser_than_trough() {
        let spec = TraceSpec {
            arrival: Arrival::Diurnal,
            rate: 500.0,
            period_ms: 1_000,
            amplitude: 0.9,
            duration_ms: 4_000,
            ..TraceSpec::for_seed(9)
        };
        let a = spec.arrivals();
        // First half of each period is the sinusoid's positive lobe.
        let peak = a.iter().filter(|&&t| (t as u64) % 1_000 < 500).count();
        let trough = a.len() - peak;
        assert!(peak as f64 > trough as f64 * 1.5, "peak {peak} vs trough {trough}");
    }

    #[test]
    fn ops_respect_mix() {
        let spec = TraceSpec {
            query_frac: 0.5,
            insert_frac: 0.5,
            delete_frac: 0.0,
            ..TraceSpec::for_seed(3)
        };
        let ops = spec.ops(10_000);
        let q = ops.iter().filter(|o| **o == OpKind::Query).count();
        assert!(ops.iter().all(|o| *o != OpKind::Delete));
        assert!((4_000..6_000).contains(&q), "query count {q}");
        assert_eq!(ops, spec.ops(10_000), "op stream must be reproducible");
    }

    #[test]
    fn partition_weights_hot_override_dominates() {
        let spec = TraceSpec { hot_partition: 2, hot_frac: 0.9, ..TraceSpec::for_seed(4) };
        let w = spec.partition_weights(4);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(w[2] > 0.9, "hot partition weight {}", w[2]);
        assert_eq!(spec.hot_for(4), Some(2));
        // Uniform trace has no hot partition to report.
        assert_eq!(TraceSpec::default().hot_for(4), None);
        // Zipf skew concentrates mass on the top rank.
        let zipf = TraceSpec { zipf: 1.5, ..TraceSpec::for_seed(4) };
        let zw = zipf.partition_weights(8);
        let hot = zipf.hot_for(8).unwrap() as usize;
        let max = zw.iter().cloned().fold(0.0, f64::max);
        assert!(zw[hot] >= max - 1e-12);
    }
}
