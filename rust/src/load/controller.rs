//! Closed-loop elasticity controller: the policy loop that turns the
//! monitor's queue-depth signal into replica-set and routing changes.
//!
//! Each tick, per partition, the controller reads the latest sampled
//! broker backlog from the [`Monitor`](super::Monitor), normalizes it
//! per live replica, and compares against two thresholds with
//! **hysteresis**: scale-up needs `high_ticks` consecutive ticks above
//! `high_depth`, scale-down needs `low_ticks` consecutive ticks below
//! `low_depth`, and every action starts a `cooldown_ticks` refractory
//! window — three independent anti-flap guards, because a controller
//! that oscillates is worse than no controller (DIMS's dynamic
//! balancing motivates the loop; the hysteresis is standard control
//! practice).
//!
//! Actions go through the cluster's elasticity knobs:
//! [`SimCluster::scale_partition`] to grow/shrink the replica set, and
//! (with [`ControllerConfig::reroute`]) [`SimCluster::set_route_weight`]
//! to send the hot partition's sub-queries to the shortest live replica
//! queue while scaled out — without it a key-hash split keeps feeding
//! the overloaded replica half the traffic. Weight restores to 100
//! (bit-identical legacy routing) when the partition scales back to its
//! construction replica count.

use std::time::Instant;

use crate::cluster::SimCluster;
use crate::types::PartitionId;

use super::Monitor;

/// Elasticity policy knobs. The defaults favor fast reaction and slow
/// release: overloads hurt immediately, idle elastic replicas only cost
/// memory.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControllerConfig {
    /// Per-replica backlog at/above which a tick counts as overloaded.
    pub high_depth: f64,
    /// Per-replica backlog at/below which a tick counts as idle.
    pub low_depth: f64,
    /// Consecutive overloaded ticks required before scaling up.
    pub high_ticks: u32,
    /// Consecutive idle ticks required before scaling down.
    pub low_ticks: u32,
    /// Ticks after any action during which the partition holds still.
    pub cooldown_ticks: u32,
    /// Replica ceiling per partition (construction replicas included).
    pub max_replicas: usize,
    /// Also steer the scaled-out partition's sub-queries onto the
    /// shortest live replica queue (route weight 0) while elastic
    /// replicas are serving.
    pub reroute: bool,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            high_depth: 4.0,
            low_depth: 0.5,
            high_ticks: 2,
            low_ticks: 12,
            cooldown_ticks: 4,
            max_replicas: 4,
            reroute: true,
        }
    }
}

/// Per-partition hysteresis state.
#[derive(Debug, Clone, Copy, Default)]
struct PartState {
    hot_streak: u32,
    cold_streak: u32,
    cooldown: u32,
}

/// The policy loop. Owns no thread: the driver calls [`Self::tick`] on
/// its sampling cadence, so controller decisions are serialized with
/// the monitor samples they read.
pub struct ElasticityController {
    cfg: ControllerConfig,
    /// Construction replica count per partition — the scale-down floor
    /// and the "routing back to legacy" trigger.
    baseline: Vec<usize>,
    state: Vec<PartState>,
    pub scale_ups: u64,
    pub scale_downs: u64,
    first_overload_ms: Option<f64>,
    first_action_ms: Option<f64>,
}

impl ElasticityController {
    /// A controller over `cluster`'s partitions, capturing the current
    /// replica counts as the scale-down floor.
    pub fn new(cfg: ControllerConfig, cluster: &SimCluster, partitions: usize) -> Self {
        let baseline = (0..partitions)
            .map(|p| cluster.executors_for_partition(p as PartitionId).len().max(1))
            .collect();
        ElasticityController {
            cfg,
            baseline,
            state: vec![PartState::default(); partitions],
            scale_ups: 0,
            scale_downs: 0,
            first_overload_ms: None,
            first_action_ms: None,
        }
    }

    /// One policy iteration at `now_ms` (driver-clock milliseconds):
    /// read the monitor's latest depth samples, update hysteresis
    /// streaks, and act on any partition whose streak and cooldown
    /// allow it. Actions are logged into the monitor's event timeline.
    pub fn tick(&mut self, now_ms: f64, at: Instant, cluster: &SimCluster, monitor: &mut Monitor) {
        for p in 0..self.state.len() {
            let pid = p as PartitionId;
            let replicas = cluster.executors_for_partition(pid).len().max(1);
            let per_replica = monitor.last_depth(pid) / replicas as f64;
            let st = &mut self.state[p];
            if st.cooldown > 0 {
                st.cooldown -= 1;
            }
            if per_replica >= self.cfg.high_depth {
                st.hot_streak += 1;
                st.cold_streak = 0;
                if self.first_overload_ms.is_none() {
                    self.first_overload_ms = Some(now_ms);
                }
            } else if per_replica <= self.cfg.low_depth {
                st.cold_streak += 1;
                st.hot_streak = 0;
            } else {
                st.hot_streak = 0;
                st.cold_streak = 0;
            }
            if st.cooldown > 0 {
                continue;
            }
            if st.hot_streak >= self.cfg.high_ticks && replicas < self.cfg.max_replicas {
                if cluster.scale_partition(pid, replicas + 1).is_ok() {
                    self.scale_ups += 1;
                    if self.first_action_ms.is_none() {
                        self.first_action_ms = Some(now_ms);
                    }
                    if self.cfg.reroute {
                        cluster.set_route_weight(pid, 0);
                    }
                    monitor.note_event(
                        at,
                        format!(
                            "scale-up p{pid} -> {} replicas (depth/replica {per_replica:.1})",
                            replicas + 1
                        ),
                    );
                    st.hot_streak = 0;
                    st.cooldown = self.cfg.cooldown_ticks;
                }
            } else if st.cold_streak >= self.cfg.low_ticks && replicas > self.baseline[p] {
                if let Ok(live) = cluster.scale_partition(pid, replicas - 1) {
                    self.scale_downs += 1;
                    if live.len() <= self.baseline[p] {
                        // Back at the construction set: restore the
                        // exact legacy key-hash routing path.
                        cluster.set_route_weight(pid, 100);
                    }
                    monitor.note_event(at, format!("scale-down p{pid} -> {} replicas", live.len()));
                    st.cold_streak = 0;
                    st.cooldown = self.cfg.cooldown_ticks;
                }
            }
        }
    }

    /// Milliseconds from the first overloaded tick to the first
    /// scale-up — the controller-reaction bench metric. None if the
    /// trace never overloaded (or the controller never acted).
    pub fn reaction_ms(&self) -> Option<f64> {
        Some(self.first_action_ms? - self.first_overload_ms?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ControllerConfig::default();
        assert!(c.high_depth > c.low_depth);
        assert!(c.low_ticks > c.high_ticks, "release must be slower than reaction");
        assert!(c.max_replicas >= 2);
    }
}
