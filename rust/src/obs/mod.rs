//! Unified telemetry plane: per-query distributed tracing
//! ([`trace::Tracer`]), the central [`registry::MetricsRegistry`], and
//! the walk-profiling hooks' export surface.
//!
//! One [`Obs`] bundle is created per [`crate::cluster::SimCluster`] (when
//! observability resolves on — see [`ObsSpec`]) and handed to every
//! coordinator and executor. When it is absent, every instrumented seam
//! takes its pre-existing code path: no trace context is minted, no span
//! is recorded, the walk runs its `NoProbe` instantiation — bit-identical
//! to the un-instrumented system, pinned by `rust/tests/obs.rs` and the
//! `PYRAMID_OBS=off` CI leg.
//!
//! See ARCHITECTURE.md §Observability plane for the span seam diagram.

pub mod registry;
pub mod trace;

pub use registry::{Counter, Gauge, Histogram, MetricsRegistry, Scrape};
pub use trace::{Span, SpanCtx, SpanGuard, SpanId, TraceId, TraceTree, Tracer};

use registry::thread_shard;
use std::sync::Arc;

/// Environment variable controlling the default: `PYRAMID_OBS=off` (or
/// `0` / `false`) detaches the telemetry plane; anything else — including
/// unset — leaves it on. Mirrors `PYRAMID_NET` / `PYRAMID_FORCE_SCALAR`.
pub const ENV_OBS: &str = "PYRAMID_OBS";

/// Cluster-level observability knob (a [`crate::config::ClusterTopology`]
/// field, like `net`): explicit `On`/`Off` win; `Auto` defers to
/// [`ENV_OBS`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ObsSpec {
    /// Resolve from the `PYRAMID_OBS` environment variable (default on).
    #[default]
    Auto,
    On,
    Off,
}

impl ObsSpec {
    /// Whether the telemetry plane should be attached.
    pub fn resolve(self) -> bool {
        match self {
            ObsSpec::On => true,
            ObsSpec::Off => false,
            ObsSpec::Auto => !matches!(
                std::env::var(ENV_OBS).ok().as_deref(),
                Some("off") | Some("0") | Some("false")
            ),
        }
    }

    /// Spec name for config JSON round-trips.
    pub fn kind(&self) -> &'static str {
        match self {
            ObsSpec::Auto => "auto",
            ObsSpec::On => "on",
            ObsSpec::Off => "off",
        }
    }

    pub fn from_kind(kind: &str) -> Option<ObsSpec> {
        match kind {
            "auto" => Some(ObsSpec::Auto),
            "on" => Some(ObsSpec::On),
            "off" => Some(ObsSpec::Off),
            _ => None,
        }
    }
}

/// The per-cluster telemetry bundle: one tracer + one registry, shared by
/// every coordinator and executor of a `SimCluster`.
#[derive(Debug, Default)]
pub struct Obs {
    pub tracer: Arc<Tracer>,
    pub registry: Arc<MetricsRegistry>,
}

impl Obs {
    pub fn new() -> Arc<Obs> {
        Arc::new(Obs { tracer: Arc::new(Tracer::new()), registry: Arc::new(MetricsRegistry::new()) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_explicit_wins_over_env() {
        assert!(ObsSpec::On.resolve());
        assert!(!ObsSpec::Off.resolve());
    }

    #[test]
    fn spec_kind_round_trips() {
        for s in [ObsSpec::Auto, ObsSpec::On, ObsSpec::Off] {
            assert_eq!(ObsSpec::from_kind(s.kind()), Some(s));
        }
        assert_eq!(ObsSpec::from_kind("bogus"), None);
    }
}
