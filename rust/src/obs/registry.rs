//! The central metrics registry: counters, gauges and fixed-bucket
//! histograms behind one name space, with a snapshot-consistent
//! [`MetricsRegistry::scrape`] and a Prometheus-style text exposition.
//!
//! Hot-path cost model:
//!
//! * [`Counter`] is an array of cache-line-padded atomics; a thread adds
//!   to its own shard (one relaxed `fetch_add`, no false sharing) and
//!   `value()` sums the shards.
//! * [`Histogram`] is a fixed array of log-spaced bucket counters
//!   (`2^(1/16)` ratio, so any quantile estimate is within ±4.4% of the
//!   exact sample) — one `fetch_add` per observe.
//! * Related counters that must never be seen torn (the per-partition /
//!   global answered pair) are updated inside
//!   [`MetricsRegistry::coherent`], which holds the *read* side of a
//!   coherence lock; `scrape()` takes the write side, so a scrape sits
//!   between coherent update groups, never inside one.
//!
//! Legacy surfaces ([`crate::broker::BrokerMetrics`],
//! [`crate::chaos::ChaosSnapshot`], the coordinator counters, the load
//! monitor) are absorbed as **scrape sources**: closures registered by
//! the cluster that re-export those counters under registry names at
//! scrape time, so `SimCluster::observe()` is one coherent snapshot.
//!
//! The quantile math ([`quantile_from_counts`]) is deliberately the only
//! quantile implementation on the serving path: registry histograms,
//! [`crate::stats::QuantileWindow`] (the hedge estimator) and the load
//! monitor's p50/p99 all call it, so "p99" means the same thing in every
//! exposition.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Shards per counter — enough that a handful of executor threads rarely
/// collide on a cache line.
const COUNTER_SHARDS: usize = 8;

/// Histogram bucket count. Buckets are `[2^(i/16), 2^((i+1)/16))`, so
/// 384 buckets cover `1 .. 2^24` (~16.7 s in µs) with ±4.4% resolution.
pub const BUCKETS: usize = 384;

const BUCKETS_PER_OCTAVE: f64 = 16.0;

/// Bucket index of a sample (values ≤ 1 land in bucket 0; the last
/// bucket absorbs everything above the range).
#[inline]
pub fn bucket_index(v: f64) -> usize {
    if !(v > 1.0) {
        return 0;
    }
    ((v.log2() * BUCKETS_PER_OCTAVE) as usize).min(BUCKETS - 1)
}

/// Lower edge of bucket `i`.
#[inline]
pub fn bucket_lower(i: usize) -> f64 {
    (i as f64 / BUCKETS_PER_OCTAVE).exp2()
}

/// Upper edge of bucket `i`.
#[inline]
pub fn bucket_upper(i: usize) -> f64 {
    ((i + 1) as f64 / BUCKETS_PER_OCTAVE).exp2()
}

/// THE quantile implementation (see module docs): nearest-rank walk over
/// bucket counts, linearly interpolated within the landing bucket.
/// `q` is clamped to `[0, 1]`; `None` when the counts are all zero.
pub fn quantile_from_counts(counts: &[u64], q: f64) -> Option<f64> {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return None;
    }
    let q = q.clamp(0.0, 1.0);
    let rank = ((q * total as f64).ceil() as u64).max(1);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        if cum + c >= rank {
            let pos = (rank - cum) as f64 / c as f64;
            let lo = bucket_lower(i);
            return Some(lo + pos * (bucket_upper(i) - lo));
        }
        cum += c;
    }
    None
}

/// Bucket-quantile of a raw sample iterator: builds a transient count
/// array and runs [`quantile_from_counts`], so windowed estimators (the
/// hedge `QuantileWindow`) share the histogram math exactly.
pub fn quantile_of_samples(samples: impl Iterator<Item = f64>, q: f64) -> Option<f64> {
    let mut counts = [0u64; BUCKETS];
    let mut any = false;
    for v in samples {
        counts[bucket_index(v)] += 1;
        any = true;
    }
    if !any {
        return None;
    }
    quantile_from_counts(&counts, q)
}

/// Which shard the calling thread owns. Assigned round-robin on first
/// use per thread; also used by the tracer's ring shards.
pub(super) fn thread_shard() -> usize {
    use std::sync::atomic::AtomicUsize;
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: usize = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    SHARD.with(|s| *s)
}

#[repr(align(64))]
struct Cell(AtomicU64);

/// Monotone counter, sharded across cache lines.
pub struct Counter {
    cells: Vec<Cell>,
}

impl Counter {
    fn new() -> Self {
        Counter { cells: (0..COUNTER_SHARDS).map(|_| Cell(AtomicU64::new(0))).collect() }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.cells[thread_shard() % COUNTER_SHARDS].0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn value(&self) -> u64 {
        self.cells.iter().map(|c| c.0.load(Ordering::Relaxed)).sum()
    }
}

/// Last-write-wins gauge holding an `f64`.
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    fn new() -> Self {
        Gauge { bits: AtomicU64::new(0f64.to_bits()) }
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn add(&self, d: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self.bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(c) => cur = c,
            }
        }
    }

    pub fn value(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket log-spaced histogram (µs convention for latencies, but
/// unit-agnostic). One atomic add per observe.
pub struct Histogram {
    counts: Vec<AtomicU64>,
    sum_bits: AtomicU64,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    #[inline]
    pub fn observe(&self, v: f64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self
                .sum_bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(c) => cur = c,
            }
        }
    }

    /// Load a consistent-enough copy of the bucket counts.
    fn load_counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    pub fn mean(&self) -> Option<f64> {
        let n = self.count();
        if n == 0 {
            None
        } else {
            Some(self.sum() / n as f64)
        }
    }

    /// Interpolated bucket quantile; `None` when empty.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        quantile_from_counts(&self.load_counts(), q)
    }

    /// Drop all samples (windowed consumers like the load monitor reset
    /// between runs).
    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.sum_bits.store(0f64.to_bits(), Ordering::Relaxed);
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// A scrape-time re-export of a legacy metrics surface: pushes
/// `(name, value)` samples into the scrape.
pub type Source = Box<dyn Fn(&mut Vec<(String, f64)>) + Send + Sync>;

#[derive(Default)]
struct Families {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    histograms: BTreeMap<String, Arc<Histogram>>,
}

/// The registry. Metric handles are `Arc`s: look up once (a mutex-guarded
/// map access), then update lock-free forever.
pub struct MetricsRegistry {
    fam: Mutex<Families>,
    sources: Mutex<BTreeMap<String, Source>>,
    /// Writers of *related* metric groups hold the read side across the
    /// group; `scrape` holds the write side. See module docs.
    coherence: RwLock<()>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry {
            fam: Mutex::new(Families::default()),
            sources: Mutex::new(BTreeMap::new()),
            coherence: RwLock::new(()),
        }
    }

    /// Get-or-create. Labels are encoded into the name by the caller
    /// (`answered_total{partition="3"}`), Prometheus text convention.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut f = self.fam.lock().unwrap();
        Arc::clone(f.counters.entry(name.to_string()).or_insert_with(|| Arc::new(Counter::new())))
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut f = self.fam.lock().unwrap();
        Arc::clone(f.gauges.entry(name.to_string()).or_insert_with(|| Arc::new(Gauge::new())))
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut f = self.fam.lock().unwrap();
        Arc::clone(
            f.histograms.entry(name.to_string()).or_insert_with(|| Arc::new(Histogram::new())),
        )
    }

    /// Run `f` as one coherent update group: a concurrent [`scrape`]
    /// observes either none or all of its metric updates.
    ///
    /// [`scrape`]: MetricsRegistry::scrape
    pub fn coherent<R>(&self, f: impl FnOnce() -> R) -> R {
        let _g = self.coherence.read().unwrap();
        f()
    }

    /// Register (or replace) a scrape-time source re-exporting a legacy
    /// surface.
    pub fn register_source(&self, name: &str, src: Source) {
        self.sources.lock().unwrap().insert(name.to_string(), src);
    }

    pub fn unregister_source(&self, name: &str) {
        self.sources.lock().unwrap().remove(name);
    }

    /// Snapshot-consistent scrape: all native metrics plus every
    /// registered source, taken while no coherent update group is open.
    pub fn scrape(&self) -> Scrape {
        let _w = self.coherence.write().unwrap();
        let mut samples: Vec<(String, f64)> = Vec::new();
        {
            let f = self.fam.lock().unwrap();
            for (name, c) in &f.counters {
                samples.push((name.clone(), c.value() as f64));
            }
            for (name, g) in &f.gauges {
                samples.push((name.clone(), g.value()));
            }
            for (name, h) in &f.histograms {
                samples.push((format!("{name}_count"), h.count() as f64));
                samples.push((format!("{name}_sum"), h.sum()));
                samples.push((format!("{name}_p50"), h.quantile(0.50).unwrap_or(f64::NAN)));
                samples.push((format!("{name}_p99"), h.quantile(0.99).unwrap_or(f64::NAN)));
            }
        }
        {
            let sources = self.sources.lock().unwrap();
            for src in sources.values() {
                src(&mut samples);
            }
        }
        samples.sort_by(|a, b| a.0.cmp(&b.0));
        Scrape { samples }
    }
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let fam = self.fam.lock().unwrap();
        f.debug_struct("MetricsRegistry")
            .field("counters", &fam.counters.len())
            .field("gauges", &fam.gauges.len())
            .field("histograms", &fam.histograms.len())
            .finish()
    }
}

/// One scrape: sorted `(name, value)` samples.
#[derive(Debug, Clone)]
pub struct Scrape {
    pub samples: Vec<(String, f64)>,
}

impl Scrape {
    pub fn get(&self, name: &str) -> Option<f64> {
        self.samples
            .binary_search_by(|(n, _)| n.as_str().cmp(name))
            .ok()
            .map(|i| self.samples[i].1)
    }

    /// Sum of every sample whose name starts with `prefix` (per-label
    /// series roll-up).
    pub fn sum_prefix(&self, prefix: &str) -> f64 {
        self.samples.iter().filter(|(n, _)| n.starts_with(prefix)).map(|(_, v)| v).sum()
    }

    /// Prometheus text exposition. NaN (empty histogram quantiles) is
    /// emitted as `NaN`, which the Prometheus text format accepts.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_family = "";
        for (name, v) in &self.samples {
            let family = name.split('{').next().unwrap_or(name);
            if family != last_family {
                out.push_str(&format!("# TYPE {family} gauge\n"));
                last_family = family;
            }
            out.push_str(&format!("{name} {v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn counter_sums_across_threads() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("hits_total");
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.value(), 4000);
        assert_eq!(reg.scrape().get("hits_total"), Some(4000.0));
    }

    #[test]
    fn gauge_set_and_add() {
        let g = Gauge::new();
        g.set(2.5);
        g.add(1.5);
        assert_eq!(g.value(), 4.0);
    }

    #[test]
    fn histogram_quantile_within_bucket_resolution() {
        let h = Histogram::new();
        for _ in 0..512 {
            h.observe(20_000.0);
        }
        let p95 = h.quantile(0.95).unwrap();
        assert!((19_000.0..=21_000.0).contains(&p95), "p95={p95}");
        assert_eq!(h.count(), 512);
        let mean = h.mean().unwrap();
        assert!((mean - 20_000.0).abs() < 1.0);
    }

    #[test]
    fn histogram_empty_and_reset() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), None);
        h.observe(100.0);
        assert!(h.quantile(0.5).is_some());
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), None);
    }

    #[test]
    fn quantile_of_samples_matches_histogram() {
        let h = Histogram::new();
        let samples = [100.0, 200.0, 400.0, 800.0, 1600.0];
        for &s in &samples {
            h.observe(s);
        }
        for q in [0.0, 0.5, 0.9, 1.0] {
            assert_eq!(h.quantile(q), quantile_of_samples(samples.iter().copied(), q));
        }
    }

    #[test]
    fn quantile_orders_and_interpolates() {
        let mut counts = [0u64; BUCKETS];
        counts[bucket_index(2.0)] = 1;
        counts[bucket_index(4.0)] = 1;
        counts[bucket_index(100.0)] = 1;
        let lo = quantile_from_counts(&counts, 0.0).unwrap();
        let hi = quantile_from_counts(&counts, 1.0).unwrap();
        assert!((1.8..=2.2).contains(&lo), "lo={lo}");
        assert!((95.0..=105.0).contains(&hi), "hi={hi}");
        assert!(lo < hi);
    }

    #[test]
    fn scrape_never_sees_torn_coherent_groups() {
        let reg = Arc::new(MetricsRegistry::new());
        let a = reg.counter("pair_a");
        let b = reg.counter("pair_b");
        let stop = Arc::new(AtomicBool::new(false));
        let writer = {
            let (reg, a, b, stop) = (Arc::clone(&reg), a, b, Arc::clone(&stop));
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    reg.coherent(|| {
                        a.inc();
                        b.inc();
                    });
                }
            })
        };
        for _ in 0..200 {
            let s = reg.scrape();
            assert_eq!(s.get("pair_a"), s.get("pair_b"));
        }
        stop.store(true, Ordering::Relaxed);
        writer.join().unwrap();
    }

    #[test]
    fn prometheus_text_has_type_lines() {
        let reg = MetricsRegistry::new();
        reg.counter("x_total").add(3);
        reg.histogram("lat_us").observe(42.0);
        reg.register_source(
            "legacy",
            Box::new(|out| out.push(("legacy_metric".into(), 7.0))),
        );
        let text = reg.scrape().to_prometheus();
        assert!(text.contains("# TYPE x_total gauge"));
        assert!(text.contains("x_total 3"));
        assert!(text.contains("legacy_metric 7"));
        assert!(text.contains("lat_us_count 1"));
        reg.unregister_source("legacy");
        assert_eq!(reg.scrape().get("legacy_metric"), None);
    }
}
