//! The trace plane: per-query distributed tracing.
//!
//! A [`TraceId`] is minted at the coordinator front door
//! ([`crate::coordinator::CoordinatorNode::execute_batch_detailed`]) — one
//! per query, not per batch — and a [`SpanCtx`] rides inside every
//! [`crate::coordinator::QueryRequest`] (and its hedge / eviction
//! re-issues) so the executor can attach its own spans to the right
//! parent. Spans are recorded **lock-cheaply**: the [`Tracer`] keeps a
//! small array of mutex-guarded ring buffers and a thread lands on its
//! own shard, so the common case is an uncontended lock around a
//! `VecDeque` push. Assembly into a [`TraceTree`] only happens when
//! somebody asks (tests, the worst-query post-mortem dump) and scans all
//! shards.
//!
//! Stage names are the [`stage`] constants; the seam diagram lives in
//! ARCHITECTURE.md §Observability plane.

use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Identifies one end-to-end query (or one background activity).
/// Sequential from 1; `0` is reserved for background spans that belong
/// to no query (ingest pump, freeze ticks).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TraceId(pub u64);

/// Identifies one span within the tracer. `SpanId(0)` as a parent means
/// "root of its trace".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SpanId(pub u64);

/// Background trace for spans with no owning query.
pub const BACKGROUND: TraceId = TraceId(0);

/// Root marker for `Span::parent`.
pub const NO_PARENT: SpanId = SpanId(0);

/// Wire cost of a serialized trace context: trace id + parent span id +
/// send timestamp, 8 bytes each. The `Arc<Tracer>` handle itself is
/// process-local plumbing (like the reply `Sender`) and costs nothing on
/// the wire.
pub const CTX_WIRE_BYTES: usize = 24;

/// Canonical stage names, one per instrumented seam.
pub mod stage {
    /// Root: one query, fan-out to merge (coordinator wall time).
    pub const QUERY: &str = "query";
    /// Meta-HNSW routing walk (batched; same interval for the block).
    pub const ROUTE: &str = "route";
    /// Broker publish → `visible_at`: queue admission plus the priced
    /// chaos + network delays (split out as tags).
    pub const PUBLISH: &str = "publish";
    /// Executor dequeue → reply send for one sub-query.
    pub const EXEC: &str = "exec";
    /// The sub-HNSW walk inside [`EXEC`]; carries the walk-profile tags.
    pub const WALK: &str = "walk";
    /// Coordinator gather loop: fan-out end → last partial / deadline.
    pub const GATHER: &str = "gather";
    /// Per-query merge of partials into the global top-k.
    pub const MERGE: &str = "merge";
    /// A hedge duplicate was published to a second replica.
    pub const HEDGE_FIRE: &str = "hedge-fire";
    /// A due hedge was withheld by the token-bucket budget.
    pub const HEDGE_SUPPRESS: &str = "hedge-suppress";
    /// Eviction-driven re-issue of an in-flight sub-query.
    pub const REISSUE: &str = "reissue";
    /// First partial for a (query, partition) — the winning replica.
    /// Duration spans publish → arrival.
    pub const PARTIAL_WIN: &str = "partial-win";
    /// A late duplicate partial (hedge loser / chaos dup), deduplicated.
    pub const PARTIAL_LOSE: &str = "partial-lose";
    /// Executor-side update-log pump that applied at least one update.
    pub const LOG_PUMP: &str = "log-pump";
    /// Executor-side freeze-controller tick that performed work.
    pub const FREEZE: &str = "freeze";
    /// One drift-to-cutover migration of the self-healing partition
    /// plane (plan journal → copy → barrier → epoch bump → retire).
    pub const MIGRATE: &str = "migrate";
}

/// One recorded span. Times are microseconds since the tracer's epoch.
#[derive(Debug, Clone)]
pub struct Span {
    pub trace: TraceId,
    pub id: SpanId,
    pub parent: SpanId,
    pub stage: &'static str,
    pub start_us: u64,
    pub end_us: u64,
    /// Partition the span is attributed to, or -1.
    pub partition: i64,
    /// Executor / coordinator id the span ran on, or -1.
    pub node: i64,
    /// Numeric annotations (delay splits, walk-profile counters, ...).
    pub tags: Vec<(&'static str, f64)>,
}

impl Span {
    pub fn duration_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }

    pub fn tag(&self, key: &str) -> Option<f64> {
        self.tags.iter().find(|(k, _)| *k == key).map(|(_, v)| *v)
    }

    fn to_json(&self) -> Json {
        let tags = self
            .tags
            .iter()
            .map(|(k, v)| (k.to_string(), Json::num(*v)))
            .collect::<std::collections::BTreeMap<_, _>>();
        Json::obj(vec![
            ("trace", Json::num(self.trace.0 as f64)),
            ("span", Json::num(self.id.0 as f64)),
            ("parent", Json::num(self.parent.0 as f64)),
            ("stage", Json::str(self.stage)),
            ("start_us", Json::num(self.start_us as f64)),
            ("dur_us", Json::num(self.duration_us() as f64)),
            ("partition", Json::num(self.partition as f64)),
            ("node", Json::num(self.node as f64)),
            ("tags", Json::Obj(tags)),
        ])
    }
}

/// Number of ring-buffer shards. A thread hashes to one shard, so with a
/// handful of executor threads contention is rare.
const SHARDS: usize = 16;

/// Per-shard ring capacity: old spans are evicted once a shard fills, so
/// a long soak keeps the most recent traces and the pinned worst query.
const RING_CAP: usize = 4096;

struct Shard {
    ring: Mutex<VecDeque<Span>>,
}

/// The span collector. Cheap to share (`Arc`); all recording goes through
/// sharded ring buffers, ids come from shared atomic counters.
pub struct Tracer {
    epoch: Instant,
    shards: Vec<Shard>,
    next_trace: AtomicU64,
    next_span: AtomicU64,
    dropped: AtomicU64,
    /// Worst-latency query seen so far, pinned with a full copy of its
    /// spans so ring eviction cannot dismember the post-mortem artifact.
    worst: Mutex<Option<(TraceId, u64, Vec<Span>)>>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    pub fn new() -> Self {
        Tracer {
            epoch: Instant::now(),
            shards: (0..SHARDS).map(|_| Shard { ring: Mutex::new(VecDeque::new()) }).collect(),
            next_trace: AtomicU64::new(1),
            next_span: AtomicU64::new(1),
            dropped: AtomicU64::new(0),
            worst: Mutex::new(None),
        }
    }

    /// Microseconds since this tracer was created.
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Convert an externally-captured instant to tracer time.
    #[inline]
    pub fn us_of(&self, at: Instant) -> u64 {
        at.saturating_duration_since(self.epoch).as_micros() as u64
    }

    pub fn new_trace(&self) -> TraceId {
        TraceId(self.next_trace.fetch_add(1, Ordering::Relaxed))
    }

    fn new_span_id(&self) -> SpanId {
        SpanId(self.next_span.fetch_add(1, Ordering::Relaxed))
    }

    /// Spans evicted from full ring shards so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Open a span starting now.
    pub fn span(self: &Arc<Self>, trace: TraceId, parent: SpanId, stage: &'static str) -> SpanGuard {
        let start = self.now_us();
        self.span_at(trace, parent, stage, start)
    }

    /// Open a span with an externally-measured start time.
    pub fn span_at(
        self: &Arc<Self>,
        trace: TraceId,
        parent: SpanId,
        stage: &'static str,
        start_us: u64,
    ) -> SpanGuard {
        SpanGuard {
            tracer: Arc::clone(self),
            span: Span {
                trace,
                id: self.new_span_id(),
                parent,
                stage,
                start_us,
                end_us: start_us,
                partition: -1,
                node: -1,
                tags: Vec::new(),
            },
        }
    }

    /// Record a fully-formed span (both endpoints already known).
    pub fn record(&self, span: Span) {
        let shard = &self.shards[super::thread_shard() % SHARDS];
        let mut ring = shard.ring.lock().unwrap();
        if ring.len() >= RING_CAP {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(span);
    }

    /// All retained spans of `trace`, sorted by start time then id.
    pub fn spans_for(&self, trace: TraceId) -> Vec<Span> {
        let mut out = Vec::new();
        for s in &self.shards {
            let ring = s.ring.lock().unwrap();
            out.extend(ring.iter().filter(|sp| sp.trace == trace).cloned());
        }
        out.sort_by(|a, b| a.start_us.cmp(&b.start_us).then(a.id.0.cmp(&b.id.0)));
        out
    }

    /// Assemble the retained spans of `trace` into a tree. None if the
    /// trace left no spans (or they were all evicted).
    pub fn tree(&self, trace: TraceId) -> Option<TraceTree> {
        let spans = self.spans_for(trace);
        if spans.is_empty() {
            None
        } else {
            Some(TraceTree { trace, spans })
        }
    }

    /// Offer a finished query as the worst-latency candidate. If it beats
    /// the current champion its spans are copied out of the rings
    /// immediately, so the post-mortem tree survives any later eviction.
    pub fn pin_if_worst(&self, trace: TraceId, latency_us: u64) {
        let mut w = self.worst.lock().unwrap();
        let beats = w.as_ref().map(|(_, us, _)| latency_us > *us).unwrap_or(true);
        if beats {
            let spans = self.spans_for(trace);
            if !spans.is_empty() {
                *w = Some((trace, latency_us, spans));
            }
        }
    }

    /// The pinned worst-latency query trace — the run's tail exemplar —
    /// with its latency in microseconds.
    pub fn worst(&self) -> Option<(u64, TraceTree)> {
        let w = self.worst.lock().unwrap();
        w.as_ref().map(|(trace, us, spans)| {
            (*us, TraceTree { trace: *trace, spans: spans.clone() })
        })
    }
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer").field("dropped", &self.dropped()).finish()
    }
}

/// An open span. Not recorded until [`SpanGuard::finish`] — dropping a
/// guard without finishing discards the span (deliberate: error paths
/// should not leave half-open spans in the rings).
pub struct SpanGuard {
    tracer: Arc<Tracer>,
    span: Span,
}

impl SpanGuard {
    pub fn id(&self) -> SpanId {
        self.span.id
    }

    pub fn tag(&mut self, key: &'static str, value: f64) {
        self.span.tags.push((key, value));
    }

    pub fn partition(&mut self, p: u16) {
        self.span.partition = p as i64;
    }

    pub fn node(&mut self, n: u64) {
        self.span.node = n as i64;
    }

    /// Close the span now and record it.
    pub fn finish(self) {
        let end = self.tracer.now_us();
        self.finish_at(end);
    }

    /// Close the span at an externally-computed end time (e.g. publish +
    /// priced delays) and record it.
    pub fn finish_at(mut self, end_us: u64) {
        self.span.end_us = end_us.max(self.span.start_us);
        self.tracer.record(self.span);
    }
}

/// Serializable trace context carried inside broker messages. The id
/// triple is what would go on the wire ([`CTX_WIRE_BYTES`]); the tracer
/// handle stands in for the agent the receiving process would report to,
/// exactly as the reply `Sender` stands in for an open connection.
#[derive(Clone)]
pub struct SpanCtx {
    pub trace: TraceId,
    /// Parent for spans the receiving side opens (the publish span).
    pub parent: SpanId,
    /// Tracer-epoch µs at publish; lets the executor compute its queue
    /// wait without a clock handshake.
    pub sent_us: u64,
    pub tracer: Arc<Tracer>,
}

impl SpanCtx {
    /// Open a child span under this context, starting now.
    pub fn child(&self, stage: &'static str) -> SpanGuard {
        self.tracer.span(self.trace, self.parent, stage)
    }
}

impl std::fmt::Debug for SpanCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SpanCtx(trace={}, parent={})", self.trace.0, self.parent.0)
    }
}

/// The assembled spans of one trace, sorted by start time.
#[derive(Debug, Clone)]
pub struct TraceTree {
    pub trace: TraceId,
    pub spans: Vec<Span>,
}

impl TraceTree {
    /// The root span (no parent), earliest first if several.
    pub fn root(&self) -> Option<&Span> {
        self.spans.iter().find(|s| s.parent == NO_PARENT)
    }

    pub fn spans_of(&self, stage: &str) -> Vec<&Span> {
        self.spans.iter().filter(|s| s.stage == stage).collect()
    }

    pub fn stage_count(&self, stage: &str) -> usize {
        self.spans.iter().filter(|s| s.stage == stage).count()
    }

    /// Children of `parent`, in start order.
    pub fn children(&self, parent: SpanId) -> Vec<&Span> {
        self.spans.iter().filter(|s| s.parent == parent).collect()
    }

    /// End-to-end duration: root span if present, else the span hull.
    pub fn duration_us(&self) -> u64 {
        if let Some(r) = self.root() {
            return r.duration_us();
        }
        let lo = self.spans.iter().map(|s| s.start_us).min().unwrap_or(0);
        let hi = self.spans.iter().map(|s| s.end_us).max().unwrap_or(0);
        hi.saturating_sub(lo)
    }

    /// One JSON object per span, newline-separated (the JSONL artifact).
    pub fn to_json_lines(&self) -> String {
        let mut out = String::new();
        for s in &self.spans {
            out.push_str(&s.to_json().dump());
            out.push('\n');
        }
        out
    }

    /// Chrome `trace_event` format (`chrome://tracing` / Perfetto):
    /// complete ("X") events, pid = trace id, tid = node (or partition
    /// when the span never ran on a node).
    pub fn to_chrome_trace(&self) -> String {
        let events: Vec<Json> = self
            .spans
            .iter()
            .map(|s| {
                let tid = if s.node >= 0 {
                    s.node
                } else if s.partition >= 0 {
                    s.partition
                } else {
                    0
                };
                let mut args: std::collections::BTreeMap<String, Json> = s
                    .tags
                    .iter()
                    .map(|(k, v)| (k.to_string(), Json::num(*v)))
                    .collect();
                if s.partition >= 0 {
                    args.insert("partition".into(), Json::num(s.partition as f64));
                }
                Json::obj(vec![
                    ("name", Json::str(s.stage)),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(s.start_us as f64)),
                    ("dur", Json::num(s.duration_us() as f64)),
                    ("pid", Json::num(s.trace.0 as f64)),
                    ("tid", Json::num(tid as f64)),
                    ("args", Json::Obj(args)),
                ])
            })
            .collect();
        Json::obj(vec![("traceEvents", Json::Arr(events))]).dump()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracer() -> Arc<Tracer> {
        Arc::new(Tracer::new())
    }

    #[test]
    fn span_guard_records_on_finish_only() {
        let t = tracer();
        let tr = t.new_trace();
        let g = t.span(tr, NO_PARENT, stage::QUERY);
        drop(g); // discarded, never recorded
        assert!(t.tree(tr).is_none());
        let mut g = t.span(tr, NO_PARENT, stage::QUERY);
        g.tag("k", 3.0);
        g.finish();
        let tree = t.tree(tr).unwrap();
        assert_eq!(tree.stage_count(stage::QUERY), 1);
        assert_eq!(tree.root().unwrap().tag("k"), Some(3.0));
    }

    #[test]
    fn tree_assembles_parent_child() {
        let t = tracer();
        let tr = t.new_trace();
        let root = t.span(tr, NO_PARENT, stage::QUERY);
        let root_id = root.id();
        let mut child = t.span(tr, root_id, stage::ROUTE);
        child.partition(3);
        child.finish();
        root.finish();
        let tree = t.tree(tr).unwrap();
        assert_eq!(tree.children(root_id).len(), 1);
        assert_eq!(tree.spans_of(stage::ROUTE)[0].partition, 3);
        // Other traces don't leak in.
        let other = t.new_trace();
        let g = t.span(other, NO_PARENT, stage::QUERY);
        g.finish();
        assert_eq!(t.tree(tr).unwrap().spans.len(), 2);
    }

    #[test]
    fn exports_are_valid_json() {
        let t = tracer();
        let tr = t.new_trace();
        let mut g = t.span(tr, NO_PARENT, stage::EXEC);
        g.node(7);
        g.tag("wait_us", 12.5);
        g.finish();
        let tree = t.tree(tr).unwrap();
        for line in tree.to_json_lines().lines() {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("stage").unwrap().as_str(), Some("exec"));
        }
        let chrome = Json::parse(&tree.to_chrome_trace()).unwrap();
        let events = chrome.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("tid").unwrap().as_f64(), Some(7.0));
    }

    #[test]
    fn worst_query_is_pinned_across_eviction() {
        let t = tracer();
        let worst = t.new_trace();
        let g = t.span_at(worst, NO_PARENT, stage::QUERY, 0);
        g.finish_at(10_000);
        t.pin_if_worst(worst, 10_000);
        // Flood the rings so the worst trace's spans are evicted.
        for _ in 0..(RING_CAP * SHARDS + 64) {
            let tr = t.new_trace();
            let g = t.span(tr, NO_PARENT, stage::PUBLISH);
            g.finish();
            t.pin_if_worst(tr, 1); // never beats 10ms
        }
        assert!(t.dropped() > 0);
        let (us, tree) = t.worst().unwrap();
        assert_eq!(us, 10_000);
        assert_eq!(tree.trace, worst);
        assert_eq!(tree.stage_count(stage::QUERY), 1);
    }

    #[test]
    fn ring_eviction_is_bounded() {
        let t = tracer();
        for _ in 0..(RING_CAP + 100) {
            let tr = t.new_trace();
            t.span(tr, NO_PARENT, stage::MERGE).finish();
        }
        let total: usize = t.shards.iter().map(|s| s.ring.lock().unwrap().len()).sum();
        assert!(total <= RING_CAP * SHARDS);
    }
}
