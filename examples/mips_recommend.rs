//! MIPS recommendation scenario (paper §III-C, Fig 10).
//!
//! Models a recommender: item embeddings with a wide norm spread
//! (tiny-like), user queries answered by maximum inner product search.
//! Shows why Algorithm 5 exists: without the top-`r` replication the
//! large-norm items scatter and branch-1 precision collapses; with it,
//! one partition per query already answers accurately — at a storage
//! overhead of well under a few percent.
//!
//!     cargo run --release --example mips_recommend

use pyramid::prelude::*;
use pyramid::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 20_000);
    let d = args.get_usize("d", 64);
    let r = args.get_usize("replication", 100);

    println!("== MIPS recommendation (Algorithm 5) ==");
    let spec = SyntheticSpec::tiny_like(n, d, 13);
    let items = spec.generate();
    let users = spec.queries(200);

    // Norm bias (paper Fig 3): how much of the exact top-10 mass sits in
    // the top-norm items.
    let workload = Workload::new(items.clone(), users, Metric::Ip, 10);
    let mut norms: Vec<(u32, f32)> =
        items.norms().into_iter().enumerate().map(|(i, v)| (i as u32, v)).collect();
    norms.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let top5pct: std::collections::HashSet<u32> =
        norms[..n / 20].iter().map(|(i, _)| *i).collect();
    let in_top: usize = workload
        .ground_truth
        .iter()
        .flat_map(|r| r.iter())
        .filter(|nb| top5pct.contains(&nb.id))
        .count();
    let total: usize = workload.ground_truth.iter().map(Vec::len).sum();
    println!(
        "norm bias: top-5%-norm items hold {:.1}% of exact top-10 results (paper Fig 3: 93.1%)",
        100.0 * in_top as f64 / total as f64
    );

    let base = IndexConfig {
        sample: (n / 4).max(512),
        meta_size: 64,
        partitions: 8,
        ..IndexConfig::default()
    };
    let params = QueryParams { k: 10, branch: 1, ef: 100, meta_ef: 100 };

    let mut table = TablePrinter::new(&[
        "variant", "replication r", "stored items", "overhead", "branch-1 precision",
    ]);
    for (label, repl) in [("Alg 3 (no replication)", 0usize), ("Alg 5 (top-r replication)", r)] {
        let cfg = IndexConfig { mips_replication: repl, ..base };
        let idx = PyramidIndex::build(&items, Metric::Ip, &cfg)?;
        let results: Vec<Vec<Neighbor>> = (0..workload.queries.len())
            .map(|qi| idx.search(workload.queries.get(qi), &params))
            .collect();
        let precision = workload.precision(&results);
        let stored = idx.stored_items();
        table.row(vec![
            label.to_string(),
            repl.to_string(),
            stored.to_string(),
            format!("{:+.2}%", 100.0 * (stored as f64 - n as f64) / n as f64),
            format!("{precision:.3}"),
        ]);
    }
    table.print();
    println!("(paper Fig 10: Pyramid reaches 96.98% precision at branch 1 with r=300, +0.6% storage)");
    Ok(())
}
