//! Nightly chaos sweep (ISSUE 6 satellite; CI `chaos-nightly` job).
//!
//! Runs N seeded schedules (default 500) over one shared harness index
//! and checks every robustness invariant the runner enforces (see
//! `pyramid::chaos::runner`). Every fifth schedule runs with
//! `repart=1`, arming the self-healing partition plane's action arm and
//! its migration invariants (ISSUE 10). On the first violation it prints the
//! failing schedule line — committable verbatim to
//! `rust/tests/chaos_corpus/` — runs the minimization ladder
//! (`ChaosSpec::minimized`) to find a smaller repro, and exits
//! nonzero.
//!
//!     cargo run --release --example chaos_nightly -- --schedules 500
//!     cargo run --release --example chaos_nightly -- --smoke true
//!
//! Flags: `--schedules N` (count), `--base-seed S` (first seed),
//! `--smoke true` (tiny sweep for CI's regular job / local sanity).

use pyramid::chaos::runner::{harness_index, run_schedule_on, HARNESS_INDEX_SEED};
use pyramid::prelude::*;
use pyramid::stats::percentile;
use std::time::Instant;

fn main() -> Result<()> {
    let args = pyramid::util::cli::Args::from_env();
    let smoke = args.get_bool("smoke");
    let schedules = if smoke { 5 } else { args.get_usize("schedules", 500) };
    let base_seed = args.get_u64("base-seed", 1);

    println!("== Pyramid chaos nightly: {schedules} schedules from seed {base_seed} ==");
    let t_build = Instant::now();
    let idx = harness_index(HARNESS_INDEX_SEED)?;
    println!("harness index built in {:?}", t_build.elapsed());

    let mut recovery_ms: Vec<f64> = Vec::new();
    let mut total_violations = 0usize;
    let mut total_migrations = 0u64;
    let t0 = Instant::now();
    for i in 0..schedules {
        let spec = if smoke {
            // Short schedules so the smoke pass stays in CI budget.
            ChaosSpec { steps: 6, step_ms: 10, ..ChaosSpec::for_seed(base_seed + i as u64) }
        } else {
            ChaosSpec::for_seed(base_seed + i as u64)
        };
        // Every fifth schedule arms the self-healing plane (ISSUE 10):
        // the seeded action stream widens with `repartition` triggers —
        // one forced mid-run — and the routing-epoch / coverage-floor /
        // migration-resume invariants switch on. The other four fifths
        // keep replaying the pre-plane action stream bit-identically.
        let spec = ChaosSpec { repartition: i % 5 == 4, ..spec };
        let report = run_schedule_on(&idx, &spec)?;
        total_migrations += report.migrations;
        recovery_ms.push(report.recovery_ms as f64);
        if !report.ok() {
            total_violations += report.violations.len();
            eprintln!("\nFAILING SEED — commit this line to rust/tests/chaos_corpus/:");
            eprintln!("{spec}");
            for v in &report.violations {
                eprintln!("  violation: {v}");
            }
            eprintln!("timeline:");
            for t in &report.timeline {
                eprintln!("  {t}");
            }
            if let Some(json) = &report.worst_trace_json {
                // Post-mortem for the tail query of the failing run; the
                // chaos-nightly job uploads this as a CI artifact.
                let path = format!("chaos_worst_trace_seed{}.jsonl", spec.seed);
                match std::fs::write(&path, json) {
                    Ok(()) => eprintln!("worst-query trace written to {path}"),
                    Err(e) => eprintln!("could not write worst-query trace: {e}"),
                }
            }
            minimize(&idx, &spec);
            eprintln!(
                "\n{} violation(s) at seed {} after {} clean schedule(s).",
                report.violations.len(),
                spec.seed,
                i
            );
            std::process::exit(1);
        }
        if (i + 1) % 50 == 0 || i + 1 == schedules {
            println!(
                "  {}/{} clean ({:.1}s elapsed, recovery p99 {:.0} ms)",
                i + 1,
                schedules,
                t0.elapsed().as_secs_f64(),
                percentile(&recovery_ms, 99.0)
            );
        }
    }
    println!(
        "all {schedules} schedules clean; {total_violations} violations; \
         {total_migrations} live migration(s); recovery p50 {:.0} ms, p99 {:.0} ms",
        percentile(&recovery_ms, 50.0),
        percentile(&recovery_ms, 99.0)
    );
    Ok(())
}

/// Walk the minimization ladder: try each strictly-smaller candidate,
/// recursing into the first one that still violates, and print the
/// smallest failing schedule found.
fn minimize(idx: &PyramidIndex, spec: &ChaosSpec) {
    eprintln!("\nminimizing (ladder of {} candidates per level)...", spec.minimized().len());
    let mut current = *spec;
    loop {
        let mut smaller: Option<ChaosSpec> = None;
        for cand in current.minimized() {
            match run_schedule_on(idx, &cand) {
                Ok(r) if !r.ok() => {
                    smaller = Some(cand);
                    break;
                }
                Ok(_) => {}
                Err(e) => eprintln!("  candidate errored (skipped): {e}"),
            }
        }
        match smaller {
            Some(s) => {
                eprintln!("  still fails: {s}");
                current = s;
            }
            None => break,
        }
    }
    eprintln!("minimized repro:\n{current}");
}
