//! Quickstart: build a Pyramid index over a synthetic dataset, search it
//! locally, and verify result quality against exact ground truth.
//!
//!     cargo run --release --example quickstart
//!
//! Flags: --n 50000 --d 96 --partitions 10 --meta 128 --branch 4

use pyramid::prelude::*;
use pyramid::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 50_000);
    let d = args.get_usize("d", 96);
    let w = args.get_usize("partitions", 10);
    let m = args.get_usize("meta", 128);
    let branch = args.get_usize("branch", 4);

    println!("== Pyramid quickstart ==");
    println!("dataset: deep-like synthetic, {n} x {d}");
    let spec = SyntheticSpec::deep_like(n, d, 7);
    let data = spec.generate();
    let queries = spec.queries(100);

    // Build the two-level index (Algorithm 3).
    let cfg = IndexConfig {
        sample: (n / 10).max(m),
        meta_size: m,
        partitions: w,
        ..IndexConfig::default()
    };
    let t0 = std::time::Instant::now();
    let index = PyramidIndex::build(&data, Metric::L2, &cfg)?;
    println!(
        "built index in {:?}: meta-HNSW {} vertices, {} partitions, sizes {:?}",
        t0.elapsed(),
        index.meta.len(),
        index.partitions(),
        index.report.sub_sizes
    );

    // Search (Algorithm 4) and score precision against brute force.
    let workload = Workload::new(data, queries, Metric::L2, 10);
    let params = QueryParams { k: 10, branch, ef: 100, meta_ef: 100 };
    let mut results = Vec::new();
    let mut touched = 0usize;
    let t0 = std::time::Instant::now();
    for qi in 0..workload.queries.len() {
        let (res, parts) = index.search_with_route(workload.queries.get(qi), &params);
        touched += parts.len();
        results.push(res);
    }
    let elapsed = t0.elapsed();
    let precision = workload.precision(&results);
    let access_rate = touched as f64 / (workload.queries.len() * w) as f64;
    println!(
        "searched {} queries in {:?} ({:.0} qps single-threaded)",
        workload.queries.len(),
        elapsed,
        workload.queries.len() as f64 / elapsed.as_secs_f64()
    );
    println!("precision@10 = {precision:.3}   access rate = {access_rate:.2} (branch K={branch})");
    println!("\nTop-5 for query 0:");
    for nb in results[0].iter().take(5) {
        println!("  id {:>7}  score {:+.4}", nb.id, nb.score);
    }
    Ok(())
}
