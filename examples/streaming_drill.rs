//! Streaming-ingest drill: live writes under live reads, with a replica
//! kill mid-ingest (PR 4).
//!
//! A writable cluster (`SimCluster::start_ingesting`, one replica per
//! partition so there is no surviving sibling to hide behind) serves a
//! closed-loop query workload while a writer streams novel vectors in
//! through the coordinator write path. A third of the way in, one
//! executor is killed mid-ingest; the Master respawns it and the
//! replacement replays the partition's sequence-numbered update log from
//! scratch. At the end the drill **asserts** that every vector ever
//! inserted — including those published while the replica was dead — is
//! its own top-1 through `execute`, i.e. replayed updates are
//! searchable after the respawn.
//!
//!     cargo run --release --example streaming_drill -- --seconds 12

use pyramid::prelude::*;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let args = pyramid::util::cli::Args::from_env();
    let n = args.get_usize("n", 20_000);
    let seconds = args.get_f64("seconds", 12.0);
    let partitions = 4usize;

    println!("== Pyramid streaming-ingest drill ==");
    let spec = SyntheticSpec::sift_like(n, 32, 7);
    let data = spec.generate();
    let queries = spec.queries(400);
    let cfg = IndexConfig {
        sample: (n / 4).max(1_000),
        meta_size: 128,
        partitions,
        ..IndexConfig::default()
    };
    let index = PyramidIndex::build(&data, Metric::L2, &cfg)?;
    // One replica per partition: a killed executor leaves its partition
    // dark until the respawn, so "searchable again" can only mean the
    // replacement replayed the update log.
    let topo = ClusterTopology {
        workers: partitions,
        replicas: 1,
        coordinators: 2,
        net_latency_us: 20,
        rebalance_ms: 150,
        executor_batch: 8,
        ..ClusterTopology::default()
    };
    // Default IngestConfig: the re-freeze threshold (512) is small enough
    // that sustained ingest exercises background compaction for real.
    let cluster = SimCluster::start_ingesting(
        &index,
        topo,
        IngestConfig::default(),
        CoordinatorConfig::default(),
    )?;
    let params = QueryParams { k: 10, branch: 3, ef: 100, meta_ef: 100 };

    let window = Duration::from_millis(500);
    let buckets: Vec<AtomicUsize> = (0..(seconds / window.as_secs_f64()).ceil() as usize + 2)
        .map(|_| AtomicUsize::new(0))
        .collect();
    let stop = AtomicBool::new(false);
    // Every (id, vector) the writer managed to publish, for the final
    // replay audit. (id, inserted-while-dead) pairs are flagged so the
    // report can call out the replayed ones explicitly.
    let inserted: Mutex<Vec<(VectorId, Vec<f32>, bool)>> = Mutex::new(Vec::new());
    let dead_window = AtomicBool::new(false);
    let t0 = Instant::now();
    let phase = |label: &str, t: f64| println!("  t={t:>5.1}s  {label}");

    std::thread::scope(|s| {
        // Closed-loop readers.
        for c in 0..8 {
            let cluster = &cluster;
            let queries = &queries;
            let stop = &stop;
            let buckets = &buckets;
            let params = &params;
            s.spawn(move || {
                let mut qi = c;
                while !stop.load(Ordering::Relaxed) {
                    if cluster.execute(queries.get(qi % queries.len()), params).is_ok() {
                        let idx = (t0.elapsed().as_secs_f64() / window.as_secs_f64()) as usize;
                        if let Some(b) = buckets.get(idx) {
                            b.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    qi += 8;
                }
            });
        }
        // The writer: novel vectors (offset + unique jitter, so each is
        // its own exact nearest neighbor) at a steady clip.
        s.spawn(|| {
            let mut j = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let base = data.get(j % n);
                let v: Vec<f32> =
                    base.iter().map(|x| x + 0.75 + (j as f32) * 1e-4).collect();
                match cluster.insert(&v) {
                    Ok(id) => {
                        let dead = dead_window.load(Ordering::Relaxed);
                        inserted.lock().unwrap().push((id, v, dead));
                    }
                    Err(e) => println!("  insert failed: {e}"),
                }
                j += 1;
                std::thread::sleep(Duration::from_millis(2));
            }
        });
        // The injection script: kill one executor a third of the way in.
        s.spawn(|| {
            let third = seconds / 3.0;
            std::thread::sleep(Duration::from_secs_f64(third));
            if let Some(&victim) = cluster.executors_for_partition(0).first() {
                phase(
                    &format!("KILL executor {victim} (sole replica of partition 0) mid-ingest"),
                    t0.elapsed().as_secs_f64(),
                );
                dead_window.store(true, Ordering::Relaxed);
                cluster.kill_executor(victim);
            }
            // Wait out the session expiry + Master respawn, then flag the
            // dead window closed once the partition serves again.
            loop {
                std::thread::sleep(Duration::from_millis(50));
                if !cluster.executors_for_partition(0).is_empty() {
                    break;
                }
            }
            phase("partition 0 respawned — replaying its update log", t0.elapsed().as_secs_f64());
            dead_window.store(false, Ordering::Relaxed);
            std::thread::sleep(Duration::from_secs_f64(
                (seconds - t0.elapsed().as_secs_f64()).max(0.1),
            ));
            stop.store(true, Ordering::Relaxed);
        });
    });

    // Freshness barrier: every live replica applies its full log.
    assert!(
        cluster.wait_ingest_idle(Duration::from_secs(30)),
        "replicas never converged on the update log"
    );

    // The audit: EVERY insert — before, during and after the kill — must
    // be its own top-1 through execute. The during-kill ones prove the
    // respawned replica replayed updates it never saw live.
    let log = inserted.into_inner().unwrap();
    let total = log.len();
    let while_dead = log.iter().filter(|(_, _, dead)| *dead).count();
    let mut checked = 0usize;
    for (id, v, dead) in &log {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let res = cluster.execute(v, &params)?;
            if res.first().map(|n| n.id) == Some(*id) {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "inserted id {id} (dead-window: {dead}) not searchable after respawn replay"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
        checked += 1;
    }
    println!(
        "\naudit: {checked}/{total} inserted vectors searchable \
         ({while_dead} published while partition 0 was dark — replayed on respawn)"
    );
    assert_eq!(checked, total);

    println!("\nthroughput timeline ({}ms buckets):", window.as_millis());
    let max = buckets.iter().map(|b| b.load(Ordering::Relaxed)).max().unwrap_or(1).max(1);
    for (i, b) in buckets.iter().enumerate() {
        let v = b.load(Ordering::Relaxed);
        if (i as f64) * window.as_secs_f64() > seconds {
            break;
        }
        let qps = v as f64 / window.as_secs_f64();
        let bar = "#".repeat(v * 60 / max);
        println!("  {:>5.1}s {:>8.0} qps |{bar}", i as f64 * window.as_secs_f64(), qps);
    }
    let inserts: u64 = cluster
        .coordinators()
        .iter()
        .map(|c| c.metrics.inserts_published.load(Ordering::Relaxed))
        .sum();
    println!(
        "\ningest counters: {inserts} inserts published, {} background re-freezes",
        cluster.total_refreezes()
    );
    println!("(expect: query dip at the kill, recovery after respawn; all inserts audited OK)");
    cluster.shutdown();
    Ok(())
}
