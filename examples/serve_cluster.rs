//! End-to-end serving driver (DESIGN.md: the required E2E validation).
//!
//! Builds a Pyramid index over a real-sized synthetic workload, starts the
//! full simulated 10-worker cluster (broker + registry + master +
//! executors + coordinators), loads it with closed-loop clients, and
//! reports throughput / P50/P90/P99 latency / precision — the paper's
//! §V-B serving metrics. With `--pjrt` the coordinators re-rank merged
//! partials through the AOT-compiled Pallas scorer (PJRT on the request
//! path); run `make artifacts` first.
//!
//!     cargo run --release --example serve_cluster -- --n 100000 --seconds 15
//!     cargo run --release --example serve_cluster -- --pjrt

use pyramid::prelude::*;
use pyramid::runtime::{default_artifacts_dir, BatchScorer, PjrtScorer};
use pyramid::util::cli::Args;
use std::sync::Arc;
use std::time::Duration;

fn main() -> Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 100_000);
    let d = args.get_usize("d", 96);
    let workers = args.get_usize("workers", 10);
    let seconds = args.get_f64("seconds", 10.0);
    let clients = args.get_usize("clients", 32);
    let branch = args.get_usize("branch", 4);
    let use_pjrt = args.get_bool("pjrt");

    println!("== Pyramid end-to-end serving ==");
    println!("dataset: deep-like {n} x {d}; cluster: {workers} workers");
    let spec = SyntheticSpec::deep_like(n, d, 7);
    let data = spec.generate();
    let queries = spec.queries(1_000);

    let cfg = IndexConfig {
        sample: (n / 10).clamp(1_000, 100_000),
        meta_size: args.get_usize("meta", 1_000).min(n / 4),
        partitions: workers,
        ..IndexConfig::default()
    };
    let t0 = std::time::Instant::now();
    let index = PyramidIndex::build(&data, Metric::L2, &cfg)?;
    println!(
        "index built in {:?} (kmeans {:?}, meta {:?}, partition {:?}, assign {:?}, subs {:?})",
        index.report.total(),
        index.report.sample_kmeans,
        index.report.meta_build,
        index.report.partition,
        index.report.assign,
        index.report.sub_build,
    );
    let _ = t0;

    println!("computing exact ground truth…");
    let workload = Workload::new(data, queries, Metric::L2, 10);

    let topo = ClusterTopology {
        workers,
        replicas: args.get_usize("replicas", 1),
        coordinators: args.get_usize("coordinators", 2),
        net_latency_us: args.get_u64("net-latency-us", 50),
        rebalance_ms: 200,
        executor_batch: args.get_usize("executor-batch", 8),
        ..ClusterTopology::default()
    };
    let scorer: Option<Arc<dyn BatchScorer>> = if use_pjrt {
        let dir = default_artifacts_dir()
            .ok_or_else(|| PyramidError::Artifact("artifacts not found; run `make artifacts`".into()))?;
        println!("PJRT re-rank enabled (artifacts: {})", dir.display());
        Some(Arc::new(PjrtScorer::spawn(dir)?))
    } else {
        None
    };
    let cluster = SimCluster::start_with_scorer(&index, topo, scorer)?;
    println!("cluster up: {} executors live", cluster.live_executors());

    let params = QueryParams { k: 10, branch, ef: 100, meta_ef: 100 };
    println!("driving {clients} closed-loop clients for {seconds}s…");
    let report = drive_cluster(&cluster, &workload, &params, clients, Duration::from_secs_f64(seconds));

    let mut t = TablePrinter::new(&[
        "branch K", "queries", "qps", "precision", "p50 ms", "p90 ms", "p99 ms", "errors",
    ]);
    t.row(vec![
        branch.to_string(),
        report.queries.to_string(),
        format!("{:.0}", report.qps),
        format!("{:.4}", report.precision),
        format!("{:.3}", report.latency.p50_ms()),
        format!("{:.3}", report.latency.p90_ms()),
        format!("{:.3}", report.latency.p99_ms()),
        report.errors.to_string(),
    ]);
    t.print();
    println!(
        "executor requests served: {} (access rate ≈ {:.2})",
        cluster.total_served(),
        cluster.total_served() as f64 / report.queries.max(1) as f64 / workers as f64
    );
    cluster.shutdown();
    Ok(())
}
