//! Load drill (ISSUE 7 tentpole): replay a trace against the simulated
//! cluster, with or without the closed-loop elasticity controller, and
//! print/export the monitor's report.
//!
//!     cargo run --release --example load_drill
//!     cargo run --release --example load_drill -- \
//!         --trace "seed=7 rate=400 duration_ms=1500 hot=2 hot_frac=0.9" \
//!         --elastic true --throttle-host 2 --cpu-share 5 --json out.json
//!
//! Flags: `--trace "<key=value ...>"` (the EXPERIMENTS.md §10 grammar),
//! `--elastic true` (enable the controller), `--throttle-host H` /
//! `--cpu-share S` (straggler injection on host H at S% CPU),
//! `--json PATH` (write the full monitor export), `--clients N`,
//! `--trace-json PATH` (dump the worst-p99 query's TraceTree as JSON lines).

use pyramid::chaos::runner::{harness_index, HARNESS_INDEX_SEED};
use pyramid::prelude::*;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let args = pyramid::util::cli::Args::from_env();
    let trace_line =
        args.get_or("trace", "seed=7 rate=400 duration_ms=1500 hot=2 hot_frac=0.9");
    let spec = TraceSpec::parse(&trace_line)?;
    let elastic = args.get_bool("elastic");
    let throttle_host = args.get("throttle-host").and_then(|s| s.parse::<usize>().ok());
    let cpu_share = args.get_u64("cpu-share", 5) as u32;
    let clients = args.get_usize("clients", 16);

    println!("== Pyramid load drill ==");
    println!("trace:   {spec}");
    println!("elastic: {elastic}");

    let t_build = Instant::now();
    let idx = harness_index(HARNESS_INDEX_SEED)?;
    println!("harness index built in {:?}", t_build.elapsed());

    let topo = ClusterTopology {
        workers: 4,
        replicas: 1,
        coordinators: 2,
        net_latency_us: 1_000,
        rebalance_ms: 50,
        executor_batch: 4,
        ..ClusterTopology::default()
    };
    let coord = CoordinatorConfig {
        timeout: Duration::from_secs(10),
        hedge: HedgeConfig::disabled(),
        ..CoordinatorConfig::default()
    };
    let cluster = SimCluster::start_with(&idx, topo, None, coord)?;
    if let Some(h) = throttle_host {
        println!("throttling host {h} to {cpu_share}% CPU");
        cluster.set_cpu_share(h, cpu_share);
    }

    let cfg = LoadConfig {
        clients,
        controller: elastic.then(ControllerConfig::default),
        ..LoadConfig::default()
    };
    let report = run_trace(&cluster, &idx, &spec, &cfg)?;
    let worst = cluster.worst_trace();
    cluster.shutdown();

    println!("\n-- report --");
    println!("queries:      {} ({:.0} qps over {:.0} ms)", report.queries, report.qps, report.wall_ms);
    println!("writes:       {} inserts / {} deletes, {} errors", report.inserts, report.deletes, report.errors);
    println!("latency:      p50 {:.0} us, p99 {:.0} us", report.p50_us, report.p99_us);
    if let Some(p) = report.hot_partition {
        println!(
            "hot p{p}:       {} queries, p99 {:.0} us",
            report.hot_queries, report.hot_p99_us
        );
    }
    println!("min coverage: {:.3}", report.min_coverage);
    if elastic {
        println!(
            "controller:   {} scale-up(s), {} scale-down(s), reaction {}",
            report.scale_ups,
            report.scale_downs,
            report
                .reaction_ms
                .map(|ms| format!("{ms:.0} ms"))
                .unwrap_or_else(|| "n/a".to_string()),
        );
        for (t, e) in &report.events {
            println!("  [{t:>7.0} ms] {e}");
        }
    }

    if let Some((us, tree)) = &worst {
        println!(
            "worst query:  {:.1} ms end-to-end, {} spans (trace {})",
            *us as f64 / 1_000.0,
            tree.spans.len(),
            tree.trace.0
        );
        if let Some(path) = args.get("trace-json") {
            std::fs::write(&path, tree.to_json_lines())?;
            println!("worst-query trace written to {path}");
        }
    }

    if let Some(path) = args.get("json") {
        std::fs::write(&path, &report.json)?;
        println!("monitor export written to {path}");
    }
    Ok(())
}
