//! Robustness drill (paper §V-D, Figs 12–13).
//!
//! Runs the replicated cluster under continuous load while injecting:
//!  1. a straggler (one host throttled to a CPU share),
//!  2. a machine kill,
//!  3. the machine rejoining,
//! and prints the throughput timeline — the dips and recoveries of Fig 13
//! and the straggler plateau of Fig 12 are directly visible.
//!
//!     cargo run --release --example failure_drill -- --seconds 24

use pyramid::prelude::*;
use pyramid::util::cli::Args;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 30_000);
    let seconds = args.get_f64("seconds", 24.0);
    let workers = 5usize;

    println!("== Pyramid failure/straggler drill ==");
    let spec = SyntheticSpec::sift_like(n, 64, 3);
    let data = spec.generate();
    let queries = spec.queries(500);
    let cfg = IndexConfig {
        sample: (n / 4).max(1_000),
        meta_size: 200,
        partitions: workers,
        ..IndexConfig::default()
    };
    let index = PyramidIndex::build(&data, Metric::L2, &cfg)?;
    // Two replicas per sub-HNSW on different hosts (the paper's setup).
    let topo = ClusterTopology {
        workers,
        replicas: 2,
        coordinators: 2,
        net_latency_us: 20,
        rebalance_ms: 150,
        executor_batch: 8,
        ..ClusterTopology::default()
    };
    let cluster = SimCluster::start(&index, topo)?;
    let params = QueryParams { k: 10, branch: 2, ef: 100, meta_ef: 100 };

    // Closed-loop clients + a 0.5s-bucket completion counter.
    let window = Duration::from_millis(500);
    let buckets: Vec<AtomicUsize> =
        (0..(seconds / window.as_secs_f64()).ceil() as usize + 2).map(|_| AtomicUsize::new(0)).collect();
    let stop = AtomicBool::new(false);
    let t0 = Instant::now();
    let phase = |label: &str, t: f64| println!("  t={t:>5.1}s  {label}");
    std::thread::scope(|s| {
        for c in 0..16 {
            let cluster = &cluster;
            let queries = &queries;
            let stop = &stop;
            let buckets = &buckets;
            let params = &params;
            s.spawn(move || {
                let mut qi = c;
                while !stop.load(Ordering::Relaxed) {
                    if cluster.execute(queries.get(qi % queries.len()), params).is_ok() {
                        let idx = (t0.elapsed().as_secs_f64() / window.as_secs_f64()) as usize;
                        if let Some(b) = buckets.get(idx) {
                            b.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    qi += 16;
                }
            });
        }
        // The injection script.
        s.spawn(|| {
            let q = seconds / 8.0;
            std::thread::sleep(Duration::from_secs_f64(q));
            phase("inject straggler: host 0 throttled to 30% CPU", t0.elapsed().as_secs_f64());
            cluster.set_cpu_share(0, 30);
            std::thread::sleep(Duration::from_secs_f64(q));
            phase("straggler cleared", t0.elapsed().as_secs_f64());
            cluster.set_cpu_share(0, 100);
            std::thread::sleep(Duration::from_secs_f64(q));
            if let Some(&victim) = cluster.executors_for_partition(0).first() {
                phase(
                    &format!("KILL single executor {victim} (one replica of sub-HNSW 0)"),
                    t0.elapsed().as_secs_f64(),
                );
                cluster.kill_executor(victim);
            }
            std::thread::sleep(Duration::from_secs_f64(q));
            phase("KILL host 1", t0.elapsed().as_secs_f64());
            cluster.kill_host(1);
            std::thread::sleep(Duration::from_secs_f64(2.0 * q));
            phase("host 1 rejoins", t0.elapsed().as_secs_f64());
            cluster.restart_host(1);
            std::thread::sleep(Duration::from_secs_f64(q));
            phase("restore(): heal all hosts/roles", t0.elapsed().as_secs_f64());
            cluster.restore();
            std::thread::sleep(Duration::from_secs_f64(q));
            stop.store(true, Ordering::Relaxed);
        });
    });

    println!("\nthroughput timeline ({}ms buckets):", window.as_millis());
    let max = buckets.iter().map(|b| b.load(Ordering::Relaxed)).max().unwrap_or(1).max(1);
    for (i, b) in buckets.iter().enumerate() {
        let v = b.load(Ordering::Relaxed);
        if (i as f64) * window.as_secs_f64() > seconds {
            break;
        }
        let qps = v as f64 / window.as_secs_f64();
        let bar = "#".repeat(v * 60 / max);
        println!("  {:>5.1}s {:>8.0} qps |{bar}", i as f64 * window.as_secs_f64(), qps);
    }
    println!("\n(expect: dip at straggler [hedging + queue rebalance], deep dip at kill,");
    println!(" brief dip at rejoin [group rebalance], then recovery — paper Figs 12-13)");
    let (hedges, reissues, dups) = cluster.coordinators().iter().fold((0u64, 0u64, 0u64), |acc, c| {
        (
            acc.0 + c.metrics.hedges_fired.load(Ordering::Relaxed),
            acc.1 + c.metrics.reissues.load(Ordering::Relaxed),
            acc.2 + c.metrics.duplicates_dropped.load(Ordering::Relaxed),
        )
    });
    println!(
        "robustness counters: {hedges} hedges fired, {reissues} eviction re-issues, \
         {dups} duplicate partials dropped"
    );
    cluster.shutdown();
    Ok(())
}
