"""L2 graph tests: rerank/top-k and kmeans_step vs jnp references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@pytest.mark.parametrize("metric", ["l2", "ip", "cos"])
def test_rerank_topk_matches_ref(metric):
    b, n, d, k = 16, 64, 32, 8
    fn, _ = model.make_rerank_topk(metric, b, n, d, k, bq=16, bn=16)
    q, x = rand((b, d), 0), rand((n, d), 1)
    vals, idx = fn(q, x, jnp.int32(n))
    rvals, ridx = ref.topk_scores(q, x, k, metric=metric)
    np.testing.assert_allclose(vals, rvals, rtol=3e-4, atol=3e-4)
    # Indices may differ on exact ties; compare the score sets instead.
    s = {"l2": ref.scores_l2, "ip": ref.scores_ip, "cos": ref.scores_cos}[
        metric
    ](q, x)
    np.testing.assert_allclose(
        np.take_along_axis(np.asarray(s), np.asarray(idx), 1),
        rvals,
        rtol=3e-4,
        atol=3e-4,
    )


def test_rerank_topk_respects_n_valid():
    b, n, d, k = 16, 64, 32, 8
    fn, _ = model.make_rerank_topk("l2", b, n, d, k, bq=16, bn=16)
    q, x = rand((b, d), 2), rand((n, d), 3)
    _, idx = fn(q, x, jnp.int32(20))
    assert bool(jnp.all(idx < 20))


def test_rerank_topk_k_larger_than_valid():
    """When n_valid < k the tail of vals must be -inf, idx still in range."""
    b, n, d, k = 16, 64, 32, 32
    fn, _ = model.make_rerank_topk("ip", b, n, d, k, bq=16, bn=16)
    q, x = rand((b, d), 4), rand((n, d), 5)
    vals, _ = fn(q, x, jnp.int32(5))
    assert bool(jnp.all(jnp.isneginf(vals[:, 5:])))
    assert bool(jnp.all(jnp.isfinite(vals[:, :5])))


def test_kmeans_step_partials_match_ref():
    n, m, d = 64, 16, 24
    fn, _ = model.make_kmeans_step(n, m, d, bq=16, bn=16)
    pts, ctr = rand((n, d), 6), rand((m, d), 7)
    w = jnp.ones((n,), jnp.float32)
    sums, counts = fn(pts, ctr, w)
    new_centers, rcounts = ref.kmeans_step(pts, ctr)
    np.testing.assert_allclose(counts, rcounts, atol=1e-5)
    # Reduce partials the way rust does and compare to the reference step.
    reduced = np.where(
        np.asarray(counts)[:, None] > 0,
        np.asarray(sums) / np.maximum(np.asarray(counts)[:, None], 1.0),
        np.asarray(ctr),
    )
    np.testing.assert_allclose(reduced, new_centers, rtol=2e-4, atol=2e-4)


def test_kmeans_step_zero_weight_padding_inert():
    """Padding points with weight 0 must not move any statistic."""
    n, m, d = 64, 16, 24
    fn, _ = model.make_kmeans_step(n, m, d, bq=16, bn=16)
    pts, ctr = rand((n, d), 8), rand((m, d), 9)
    w_full = jnp.ones((n,), jnp.float32)
    sums_a, counts_a = fn(pts, ctr, w_full)
    # Replace the last 16 points with garbage but weight 0.
    pts_b = pts.at[48:].set(1e6)
    w_b = w_full.at[48:].set(0.0)
    sums_b, counts_b = fn(pts_b, ctr, w_b)
    sums_ref, counts_ref = fn(pts[:48], ctr, w_full[:48]) if False else (
        None,
        None,
    )
    # Compare against recomputing with only the first 48 points at weight 1.
    fn48, _ = model.make_kmeans_step(48, m, d, bq=16, bn=16)
    sums_c, counts_c = fn48(pts[:48], ctr, jnp.ones((48,), jnp.float32))
    np.testing.assert_allclose(sums_b, sums_c, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(counts_b, counts_c, atol=1e-5)
    del sums_a, counts_a, sums_ref, counts_ref


def test_kmeans_step_weighted_counts():
    n, m, d = 32, 8, 16
    fn, _ = model.make_kmeans_step(n, m, d, bq=16, bn=8)
    pts, ctr = rand((n, d), 10), rand((m, d), 11)
    w = jnp.full((n,), 2.5, jnp.float32)
    _, counts = fn(pts, ctr, w)
    assert float(counts.sum()) == pytest.approx(2.5 * n, rel=1e-5)
