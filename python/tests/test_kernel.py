"""Pallas scorer kernel vs pure-jnp oracle — the core correctness signal.

Fixed-shape allclose checks plus a hypothesis sweep over shapes/metrics.
Pallas runs under interpret=True (CPU), so these are exact-semantics checks
of the tiling/epilogue logic, not hardware tests.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, scorer

jax.config.update("jax_platform_name", "cpu")

METRICS = ("l2", "ip", "cos")
REFS = {"l2": ref.scores_l2, "ip": ref.scores_ip, "cos": ref.scores_cos}


def rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@pytest.mark.parametrize("metric", METRICS)
def test_scores_matches_ref_default_tiles(metric):
    q, x = rand((128, 96), 0), rand((1024, 96), 1)
    got = scorer.scores(q, x, metric=metric)
    want = REFS[metric](q, x)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("bq,bn", [(8, 16), (32, 32), (128, 512)])
def test_scores_tile_shapes(metric, bq, bn):
    q, x = rand((bq * 2, 64), 2), rand((bn * 3, 64), 3)
    got = scorer.scores(q, x, metric=metric, bq=bq, bn=bn)
    want = REFS[metric](q, x)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("metric", METRICS)
def test_single_tile_grid(metric):
    """Grid (1,1): no tiling effects at all."""
    q, x = rand((16, 32), 4), rand((16, 32), 5)
    got = scorer.scores(q, x, metric=metric, bq=16, bn=16)
    np.testing.assert_allclose(got, REFS[metric](q, x), rtol=2e-4, atol=2e-4)


def test_l2_self_distance_zero():
    x = rand((64, 48), 6)
    s = scorer.scores(x, x, metric="l2", bq=64, bn=64)
    np.testing.assert_allclose(jnp.diag(s), jnp.zeros(64), atol=1e-3)


def test_cos_self_similarity_one():
    x = rand((64, 48), 7)
    s = scorer.scores(x, x, metric="cos", bq=64, bn=64)
    np.testing.assert_allclose(jnp.diag(s), jnp.ones(64), atol=1e-4)


def test_ip_is_plain_matmul():
    q, x = rand((32, 24), 8), rand((96, 24), 9)
    got = scorer.scores(q, x, metric="ip", bq=32, bn=32)
    np.testing.assert_allclose(got, q @ x.T, rtol=2e-4, atol=2e-4)


def test_depth_zero_padding_is_score_neutral():
    """Zero-padding d must not change any metric's scores (rust relies on
    this to serve arbitrary d with fixed-shape artifacts)."""
    q, x = rand((16, 40), 10), rand((32, 40), 11)
    qp = jnp.pad(q, ((0, 0), (0, 24)))
    xp = jnp.pad(x, ((0, 0), (0, 24)))
    for metric in METRICS:
        a = scorer.scores(q, x, metric=metric, bq=16, bn=16)
        b = scorer.scores(qp, xp, metric=metric, bq=16, bn=16)
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_masked_scores_padding_is_neg_inf():
    q, x = rand((16, 32), 12), rand((64, 32), 13)
    s = scorer.scores_masked(q, x, 40, metric="l2", bq=16, bn=16)
    assert bool(jnp.all(jnp.isinf(s[:, 40:]))) and bool(
        jnp.all(s[:, 40:] < 0)
    )
    np.testing.assert_allclose(
        s[:, :40],
        ref.scores_l2(q, x)[:, :40],
        rtol=2e-4,
        atol=2e-4,
    )


def test_masked_scores_never_win_topk():
    q, x = rand((16, 32), 14), rand((64, 32), 15)
    s = scorer.scores_masked(q, x, 10, metric="ip", bq=16, bn=16)
    _, idx = jax.lax.top_k(s, 10)
    assert bool(jnp.all(idx < 10))


@settings(max_examples=25, deadline=None)
@given(
    bq=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 64]),
    gq=st.integers(1, 3),
    gn=st.integers(1, 3),
    d=st.integers(1, 64),
    metric=st.sampled_from(METRICS),
    seed=st.integers(0, 2**31 - 1),
)
def test_scores_hypothesis_sweep(bq, bn, gq, gn, d, metric, seed):
    """Property: tiled kernel == oracle for arbitrary grid/tile/depth."""
    key = jax.random.PRNGKey(seed)
    kq, kx = jax.random.split(key)
    q = jax.random.normal(kq, (bq * gq, d), jnp.float32)
    x = jax.random.normal(kx, (bn * gn, d), jnp.float32)
    got = scorer.scores(q, x, metric=metric, bq=bq, bn=bn)
    want = REFS[metric](q, x)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@settings(max_examples=10, deadline=None)
@given(
    n_valid=st.integers(1, 48),
    metric=st.sampled_from(METRICS),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_hypothesis_sweep(n_valid, metric, seed):
    key = jax.random.PRNGKey(seed)
    kq, kx = jax.random.split(key)
    q = jax.random.normal(kq, (16, 24), jnp.float32)
    x = jax.random.normal(kx, (48, 24), jnp.float32)
    s = scorer.scores_masked(q, x, n_valid, metric=metric, bq=16, bn=16)
    want = REFS[metric](q, x)
    np.testing.assert_allclose(
        s[:, :n_valid], want[:, :n_valid], rtol=3e-4, atol=3e-4
    )
    assert bool(jnp.all(jnp.isneginf(s[:, n_valid:])))
