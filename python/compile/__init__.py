"""Build-time compile path for Pyramid (never imported at runtime).

Layer 2 (`model`) defines the jax compute graphs, calling the Layer-1 Pallas
kernels in `kernels`; `aot` lowers them to HLO text artifacts that the rust
runtime loads through PJRT.
"""
