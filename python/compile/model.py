"""L2: Pyramid's jax compute graphs, built on the L1 Pallas scorer.

Three graph families, each lowered to HLO text by `aot.py` and executed from
the rust hot path through PJRT:

  scores      — dense score block S = f(Q Xᵀ)   (ground truth, bulk scans)
  rerank_topk — masked score block + fused lax.top_k
                (the coordinator's merge/re-rank step: Algorithm 4 line 9)
  kmeans_step — one weighted Lloyd assignment+update step
                (index build: Algorithm 3 line 4 / Algorithm 5 line 5)

All shapes are static; rust pads inputs up to the compiled block shape:
  * depth d     — zero-padding is exactly score-neutral for ip/l2/cos;
  * queries B   — padded query rows produce garbage rows rust never reads;
  * items N     — masked to -inf via the `n_valid` scalar (rerank) or
                  zero `weights` (kmeans), so padding cannot leak into
                  results.
"""

import jax
import jax.numpy as jnp

from .kernels import ref, scorer


def _scores_impl(impl, metric, bq, bn):
    """Select the scoring implementation.

    "pallas": the L1 tiled kernel under interpret=True. On a real TPU this
    lowers to Mosaic and is the fast path; on CPU-PJRT the interpreter
    loop executes tile-by-tile (correct but slow), so it serves as the
    numerics cross-check.
    "jnp": the same math lowered as plain XLA ops — on CPU-PJRT this
    compiles to a fused dot and is the serving path (§Perf log in
    EXPERIMENTS.md: ~100x over interpreted Pallas on this host).
    """
    if impl == "pallas":
        return lambda q, x: scorer.scores(q, x, metric=metric, bq=bq, bn=bn)

    # jnp lowering: same math, matmul-shaped. NOTE: not ref.scores_l2 — the
    # oracle's broadcast form materializes a [B, N, d] tensor, which is
    # memory-catastrophic at serving block sizes (§Perf log). The norm
    # expansion keeps everything inside one dot.
    def l2(q, x):
        dots = q @ x.T
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        xn = jnp.sum(x * x, axis=1, keepdims=True).T
        return 2.0 * dots - qn - xn

    def cos(q, x):
        qn = q * jax.lax.rsqrt(jnp.sum(q * q, axis=1, keepdims=True) + 1e-24)
        xn = x * jax.lax.rsqrt(jnp.sum(x * x, axis=1, keepdims=True) + 1e-24)
        return qn @ xn.T

    return {"l2": l2, "ip": ref.scores_ip, "cos": cos}[metric]


def make_scores(metric, b, n, d, bq, bn, impl="pallas"):
    """Dense score block [b, n] for a fixed (b, n, d)."""

    score = _scores_impl(impl, metric, bq, bn)

    def fn(q, x):
        return (score(q, x),)

    specs = (
        jax.ShapeDtypeStruct((b, d), jnp.float32),
        jax.ShapeDtypeStruct((n, d), jnp.float32),
    )
    return fn, specs


def make_rerank_topk(metric, b, n, d, k, bq, bn, impl="pallas"):
    """Masked scores + fused top-k: (vals [b, k], idx [b, k] int32).

    `n_valid` masks padded item rows; padded rows can therefore never enter
    the top-k even when the caller's candidate set is smaller than n.
    """

    score = _scores_impl(impl, metric, bq, bn)

    def fn(q, x, n_valid):
        s = score(q, x)
        s = jnp.where(jnp.arange(x.shape[0])[None, :] < n_valid, s, -jnp.inf)
        # Top-k via a full descending sort + slice rather than jax.lax.top_k:
        # top_k lowers to the `topk` HLO instruction, which the rust side's
        # XLA 0.5.1 text parser predates. sort_key_val lowers to plain
        # `sort`, which round-trips.
        iota = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        keys, idx = jax.lax.sort_key_val(-s, iota, dimension=1)
        return -keys[:, :k], idx[:, :k]

    specs = (
        jax.ShapeDtypeStruct((b, d), jnp.float32),
        jax.ShapeDtypeStruct((n, d), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    return fn, specs


def make_kmeans_step(n, m, d, bq, bn, impl="pallas"):
    """One weighted Lloyd step over a block of points.

    points [n, d], centers [m, d], weights [n] -> (sums [m, d], counts [m]).

    Returns the *partial sufficient statistics* (weighted per-center sums and
    weight totals) rather than new centers, so rust can stream blocks of a
    large dataset through the same executable and reduce the partials —
    exactly the distributed-kmeans workflow of Algorithm 3's "Distributed
    workflow" paragraph. Points with weight 0 (padding) contribute nothing.
    The assignment distance matrix reuses the L1 Pallas scorer.
    """

    score = _scores_impl(impl, "l2", bq, bn)

    def fn(points, centers, weights):
        s = score(points, centers)  # [n, m]
        assign = jnp.argmax(s, axis=-1)  # max score = min distance
        one_hot = (assign[:, None] == jnp.arange(m)[None, :]).astype(
            jnp.float32
        ) * weights[:, None]
        counts = one_hot.sum(axis=0)  # [m]
        sums = one_hot.T @ points  # [m, d]
        return sums, counts

    specs = (
        jax.ShapeDtypeStruct((n, d), jnp.float32),
        jax.ShapeDtypeStruct((m, d), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )
    return fn, specs
