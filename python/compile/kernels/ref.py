"""Pure-jnp reference oracles for the Pallas scoring kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must match its reference here to float tolerance, across the shape/dtype
sweep in python/tests/. Kept deliberately simple — no tiling, no tricks.

Similarity convention follows the paper (Section II): larger score = more
similar. Euclidean therefore returns *negative squared* distance (the square
is monotone, so top-k is unchanged and we avoid a sqrt on the hot path).
"""

import jax
import jax.numpy as jnp


def scores_ip(q, x):
    """Inner-product scores. q: [B, d], x: [N, d] -> [B, N]."""
    return q @ x.T


def scores_l2(q, x):
    """Negative squared Euclidean distance scores. [B, d], [N, d] -> [B, N].

    Computed directly (no norm expansion) so it is an independent oracle for
    the kernel's norm-expansion trick.
    """
    diff = q[:, None, :] - x[None, :, :]
    return -jnp.sum(diff * diff, axis=-1)


def scores_cos(q, x):
    """Cosine (angular) similarity scores. [B, d], [N, d] -> [B, N]."""
    qn = q / jnp.linalg.norm(q, axis=-1, keepdims=True).clip(1e-12)
    xn = x / jnp.linalg.norm(x, axis=-1, keepdims=True).clip(1e-12)
    return qn @ xn.T


def topk_scores(q, x, k, metric="l2"):
    """Reference fused score+top-k: returns (values, indices), each [B, k]."""
    s = {"l2": scores_l2, "ip": scores_ip, "cos": scores_cos}[metric](q, x)
    vals, idx = jax.lax.top_k(s, k)
    return vals, idx


def kmeans_step(points, centers):
    """One Lloyd step. points: [N, d], centers: [m, d].

    Returns (new_centers [m, d], counts [m]). Empty clusters keep their old
    center (counts==0 -> unchanged), matching the rust implementation.
    """
    d2 = -scores_l2(points, centers)  # [N, m] squared distances
    assign = jnp.argmin(d2, axis=-1)  # [N]
    m = centers.shape[0]
    one_hot = (assign[:, None] == jnp.arange(m)[None, :]).astype(points.dtype)
    counts = one_hot.sum(axis=0)  # [m]
    sums = one_hot.T @ points  # [m, d]
    new_centers = jnp.where(
        counts[:, None] > 0, sums / counts[:, None].clip(1.0), centers
    )
    return new_centers, counts
