"""L1 Pallas kernel: tiled batched similarity scorer.

This is the paper's dense-compute hot-spot (coordinator re-rank, k-means
assignment, ground-truth scans) expressed as an MXU-friendly tiled matmul.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): Pyramid targets CPU
clusters, so there is no threadblock scheme to port; instead we tile the
score matrix S = f(Q · Xᵀ) into (BQ, BN) VMEM blocks with a grid over both
axes. The d (depth) axis is kept whole per tile — Pyramid's dimensions are
96–384, so a full row of Q and column-block of X fit comfortably in VMEM
(see DESIGN.md §7 for the footprint arithmetic). All three metrics share one
matmul; the metric is an epilogue:

  ip :  S = Q Xᵀ
  l2 :  S = -(‖q‖² + ‖x‖² - 2 Q Xᵀ)         (norm expansion; MXU does 2QXᵀ)
  cos:  S = Q̂ X̂ᵀ with rows pre-normalized inside the tile

Kernels are lowered with interpret=True only — the CPU PJRT plugin cannot
execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches both the MXU systolic array edge and the
# lane count; BN=512 amortizes the Q-tile reload across four MXU passes.
BQ = 128
BN = 512


def _scorer_kernel(metric, q_ref, x_ref, o_ref):
    """One (BQ, BN) tile of the score matrix.

    q_ref: [BQ, d] query tile, x_ref: [BN, d] item tile, o_ref: [BQ, BN].
    """
    q = q_ref[...]
    x = x_ref[...]
    if metric == "cos":
        # Normalize rows in-tile so the matmul below yields cosine directly.
        q = q * jax.lax.rsqrt(jnp.sum(q * q, axis=-1, keepdims=True) + 1e-24)
        x = x * jax.lax.rsqrt(jnp.sum(x * x, axis=-1, keepdims=True) + 1e-24)
    # The single MXU-bound contraction shared by every metric.
    dots = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [BQ, BN]
    if metric == "l2":
        qn = jnp.sum(q * q, axis=-1, keepdims=True)  # [BQ, 1]
        xn = jnp.sum(x * x, axis=-1, keepdims=True).T  # [1, BN]
        o_ref[...] = 2.0 * dots - qn - xn
    else:
        o_ref[...] = dots


def scores(q, x, metric="l2", bq=BQ, bn=BN):
    """Tiled scorer: q [B, d], x [N, d] -> scores [B, N].

    B must be a multiple of bq and N a multiple of bn (the AOT pipeline pads
    to block shape; rust slices the valid region). Larger score = more
    similar for every metric (l2 returns negative squared distance).
    """
    B, d = q.shape
    N, _ = x.shape
    assert B % bq == 0 and N % bn == 0, (B, N, bq, bn)
    grid = (B // bq, N // bn)
    return pl.pallas_call(
        functools.partial(_scorer_kernel, metric),
        grid=grid,
        in_specs=[
            # Q tile varies with grid axis 0 only: reused across item blocks.
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            # X tile varies with grid axis 1 only: streamed HBM->VMEM.
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=True,
    )(q, x)


def scores_masked(q, x, n_valid, metric="l2", bq=BQ, bn=BN):
    """Like scores() but masks padded item rows to -inf.

    n_valid is a scalar (static or traced) count of real rows in x; rows at
    index >= n_valid receive -inf so they can never enter a top-k. Used by
    the AOT re-rank artifact, whose item block is padded to the block shape.
    """
    s = scores(q, x, metric=metric, bq=bq, bn=bn)
    idx = jnp.arange(x.shape[0])[None, :]
    return jnp.where(idx < n_valid, s, -jnp.inf)
