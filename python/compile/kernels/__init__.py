"""L1 Pallas kernels for Pyramid's dense scoring hot-spot.

`scorer` holds the tiled kernels; `ref` holds the pure-jnp oracles used by
pytest. Everything here is build-time only — rust consumes the lowered HLO.
"""

from . import ref, scorer  # noqa: F401
from .scorer import scores, scores_masked  # noqa: F401
