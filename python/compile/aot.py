"""AOT pipeline: lower the L2 graphs to HLO *text* artifacts + manifest.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids that
the rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per variant plus ``manifest.json`` describing
every artifact's function family, metric and static shapes — the rust
runtime keys its executable cache off this manifest.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# Static-shape variants compiled for the rust runtime. d=128 covers every
# dataset dim in the paper's range <=128 by zero-padding; d=384 covers the
# Tiny-like GIST dims. Block sizes divide the shapes (scorer asserts this).
VARIANTS = []


def _add(name, fn, specs, **meta):
    VARIANTS.append({"name": name, "fn": fn, "specs": specs, "meta": meta})


def build_variants():
    """Every (family, metric, d) is emitted in BOTH implementations:

    * impl="pallas" — the L1 tiled kernel (interpret=True). The TPU-target
      artifact and the on-PJRT numerics cross-check.
    * impl="jnp"    — identical math as plain XLA ops; compiles to fused
      dots on CPU-PJRT and is the serving path there (§Perf).
    """
    del VARIANTS[:]
    for impl in ("pallas", "jnp"):
        sfx = "" if impl == "pallas" else "_jnp"
        for metric in ("l2", "ip", "cos"):
            for d in (128, 384):
                # Re-rank blocks: B=1 (the coordinator's per-query merge —
                # no padded-batch waste) and B=128 (batched re-rank).
                for b in (1, 128):
                    n, k, bn = 512, 128, 512
                    bq = b
                    fn, specs = model.make_rerank_topk(metric, b, n, d, k, bq, bn, impl=impl)
                    _add(
                        f"rerank_{metric}_b{b}_n{n}_d{d}_k{k}{sfx}",
                        fn,
                        specs,
                        family="rerank",
                        impl=impl,
                        metric=metric,
                        b=b,
                        n=n,
                        d=d,
                        k=k,
                    )
                # Bulk score block for ground-truth / replication scans.
                b, n, bq, bn = 128, 4096, 128, 512
                fn, specs = model.make_scores(metric, b, n, d, bq, bn, impl=impl)
                _add(
                    f"scores_{metric}_b{b}_n{n}_d{d}{sfx}",
                    fn,
                    specs,
                    family="scores",
                    impl=impl,
                    metric=metric,
                    b=b,
                    n=n,
                    d=d,
                )
        for d in (128, 384):
            n, m, bq, bn = 4096, 512, 128, 512
            fn, specs = model.make_kmeans_step(n, m, d, bq, bn, impl=impl)
            _add(
                f"kmeans_step_n{n}_m{m}_d{d}{sfx}",
                fn,
                specs,
                family="kmeans_step",
                impl=impl,
                n=n,
                m=m,
                d=d,
            )


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def input_fingerprint() -> str:
    """Hash of the compile-path sources, for artifact staleness checks."""
    here = os.path.dirname(__file__)
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    fp = input_fingerprint()
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    if not args.force and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        if old.get("fingerprint") == fp and all(
            os.path.exists(os.path.join(args.out_dir, a["file"]))
            for a in old.get("artifacts", [])
        ):
            print(f"artifacts up to date (fingerprint {fp}); nothing to do")
            return

    build_variants()
    entries = []
    for v in VARIANTS:
        lowered = jax.jit(v["fn"]).lower(*v["specs"])
        text = to_hlo_text(lowered)
        fname = v["name"] + ".hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        entries.append({"name": v["name"], "file": fname, **v["meta"]})
        print(f"wrote {fname} ({len(text)} chars)")

    with open(manifest_path, "w") as f:
        json.dump({"fingerprint": fp, "artifacts": entries}, f, indent=2)
    print(f"wrote manifest.json ({len(entries)} artifacts, fingerprint {fp})")


if __name__ == "__main__":
    main()
