//! Figure/table regeneration harness — one subcommand per table & figure
//! in the paper's evaluation (DESIGN.md §5 maps each to its modules).
//!
//!     cargo bench --bench figures -- <cmd> [--scale 0.5] [--seconds 3]
//!
//! Commands: fig3 fig5 fig6 fig7 fig8 fig9 fig10 fig11 fig12 fig13
//!           table_build all
//!
//! Sizes are scaled-down substitutes for the paper's 500M-point testbed
//! (DESIGN.md §3); absolute numbers differ but the *shape* of every curve
//! (who wins, monotonicity, crossovers) is the reproduction target. Every
//! row is printed in the same layout EXPERIMENTS.md records.

use pyramid::baselines::{DistributedKdForest, KdForestParams, NaiveIndex};
use pyramid::bench_harness::{drive_cluster, TablePrinter, Workload};
use pyramid::cluster::SimCluster;
use pyramid::config::{ClusterTopology, IndexConfig, QueryParams};
use pyramid::dataset::SyntheticSpec;
use pyramid::hnsw::HnswParams;
use pyramid::meta::PyramidIndex;
use pyramid::metric::Metric;
use pyramid::types::Neighbor;
use pyramid::util::cli::Args;
use std::time::Duration;

/// Shared harness configuration.
struct Ctx {
    scale: f64,
    seconds: f64,
    clients: usize,
    workers: usize,
}

impl Ctx {
    fn n(&self, base: usize) -> usize {
        ((base as f64) * self.scale) as usize
    }
}

fn main() {
    // `cargo bench -- <args>` passes everything after `--` to us; cargo
    // itself appends `--bench`, which we ignore.
    let raw: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(raw);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("all");
    let ctx = Ctx {
        scale: args.get_f64("scale", 0.35),
        seconds: args.get_f64("seconds", 2.5),
        clients: args.get_usize("clients", 16),
        workers: args.get_usize("workers", 10),
    };
    let t0 = std::time::Instant::now();
    match cmd {
        "fig3" => fig3(&ctx),
        "fig5" | "fig6" => fig5_fig6(&ctx),
        "fig7" | "fig8" => fig7_fig8(&ctx),
        "fig9" => fig9(&ctx),
        "fig10" => fig10(&ctx),
        "fig11" => fig11(&ctx),
        "fig12" => fig12(&ctx),
        "fig13" => fig13(&ctx),
        "table_build" => table_build(&ctx),
        "all" => {
            fig3(&ctx);
            fig5_fig6(&ctx);
            fig7_fig8(&ctx);
            fig9(&ctx);
            fig10(&ctx);
            fig11(&ctx);
            fig12(&ctx);
            fig13(&ctx);
            table_build(&ctx);
        }
        other => {
            eprintln!("unknown figure: {other}");
            std::process::exit(2);
        }
    }
    eprintln!("[figures] {cmd} done in {:?}", t0.elapsed());
}

fn deep(ctx: &Ctx) -> SyntheticSpec {
    let mut s = SyntheticSpec::deep_like(ctx.n(60_000), 96, 7);
    s.clusters = 128;
    s
}

fn sift(ctx: &Ctx) -> SyntheticSpec {
    let mut s = SyntheticSpec::sift_like(ctx.n(60_000), 128, 11);
    s.clusters = 128;
    s
}

fn tiny(ctx: &Ctx) -> SyntheticSpec {
    SyntheticSpec::tiny_like(ctx.n(20_000), 96, 13)
}

fn index_cfg(m: usize, w: usize, n: usize) -> IndexConfig {
    IndexConfig {
        sample: (n / 6).max(m).min(n),
        meta_size: m,
        partitions: w,
        hnsw: HnswParams::default(),
        ..IndexConfig::default()
    }
}

fn topo(ctx: &Ctx, workers: usize, replicas: usize) -> ClusterTopology {
    let _ = ctx;
    ClusterTopology {
        workers,
        replicas,
        coordinators: 2,
        net_latency_us: 20,
        rebalance_ms: 200,
        executor_batch: 8,
        ..ClusterTopology::default()
    }
}

/// Fig 3: MIPS result distribution over item-norm percentiles.
fn fig3(ctx: &Ctx) {
    println!("\n=== Fig 3: MIPS result distribution vs item norm (tiny-like) ===");
    let spec = tiny(ctx);
    let data = spec.generate();
    let queries = spec.queries(500);
    let workload = Workload::new(data.clone(), queries, Metric::Ip, 10);
    let mut norms: Vec<(u32, f32)> =
        data.norms().into_iter().enumerate().map(|(i, v)| (i as u32, v)).collect();
    norms.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let total: usize = workload.ground_truth.iter().map(Vec::len).sum();
    let mut t = TablePrinter::new(&["norm percentile (top)", "share of exact top-10 results", "paper"]);
    for (pct, paper) in [(5.0, "93.1%"), (10.0, "~96%"), (20.0, "~99%"), (50.0, "~100%")] {
        let cut = ((data.len() as f64) * pct / 100.0) as usize;
        let set: std::collections::HashSet<u32> = norms[..cut].iter().map(|(i, _)| *i).collect();
        let hits: usize = workload
            .ground_truth
            .iter()
            .flat_map(|r| r.iter())
            .filter(|nb| set.contains(&nb.id))
            .count();
        t.row(vec![
            format!("{pct}%"),
            format!("{:.1}%", 100.0 * hits as f64 / total as f64),
            paper.to_string(),
        ]);
    }
    t.print();
}

/// Figs 5 & 6: access rate and precision vs branching factor K, for three
/// meta-HNSW sizes (paper: 1k/10k/100k at 500M items; scaled here).
fn fig5_fig6(ctx: &Ctx) {
    println!("\n=== Figs 5 & 6: access rate / precision vs branching factor ===");
    let ks = [1usize, 5, 10, 20, 50, 100];
    let metas = [100usize, 400, 1600];
    for (name, spec) in [("Deep", deep(ctx)), ("SIFT", sift(ctx))] {
        let data = spec.generate();
        let queries = spec.queries(300);
        let workload = Workload::new(data.clone(), queries, Metric::L2, 10);
        let mut acc = TablePrinter::new(&["m \\ K", "1", "5", "10", "20", "50", "100"]);
        let mut prec = TablePrinter::new(&["m \\ K", "1", "5", "10", "20", "50", "100"]);
        for &m in &metas {
            let idx =
                PyramidIndex::build(&data, Metric::L2, &index_cfg(m, ctx.workers, data.len())).unwrap();
            let mut acc_row = vec![format!("m={m}")];
            let mut prec_row = vec![format!("m={m}")];
            for &k in &ks {
                let params = QueryParams { k: 10, branch: k, ef: 100, meta_ef: 100.max(k) };
                let mut touched = 0usize;
                let mut results = Vec::new();
                for qi in 0..workload.queries.len() {
                    let (res, parts) = idx.search_with_route(workload.queries.get(qi), &params);
                    touched += parts.len();
                    results.push(res);
                }
                let rate = touched as f64 / (workload.queries.len() * ctx.workers) as f64;
                acc_row.push(format!("{rate:.2}"));
                prec_row.push(format!("{:.3}", workload.precision(&results)));
            }
            acc.row(acc_row);
            prec.row(prec_row);
        }
        println!("\n[Fig 5] {name}: access rate vs K (expect: up with K, down with m)");
        acc.print();
        println!("\n[Fig 6] {name}: precision vs K (expect: up then plateau; higher for small m)");
        prec.print();
    }
}

/// Figs 7 & 8: cluster throughput and P90 latency vs branching factor.
fn fig7_fig8(ctx: &Ctx) {
    println!("\n=== Figs 7 & 8: throughput / P90 latency vs branching factor ===");
    let ks = [1usize, 5, 10, 20, 50];
    let metas = [100usize, 400, 1600];
    for (name, spec) in [("Deep", deep(ctx)), ("SIFT", sift(ctx))] {
        let data = spec.generate();
        let queries = spec.queries(500);
        let workload = Workload::new(data.clone(), queries, Metric::L2, 10);
        let mut thr = TablePrinter::new(&["m \\ K", "1", "5", "10", "20", "50"]);
        let mut lat = TablePrinter::new(&["m \\ K", "1", "5", "10", "20", "50"]);
        for &m in &metas {
            let idx =
                PyramidIndex::build(&data, Metric::L2, &index_cfg(m, ctx.workers, data.len())).unwrap();
            let cluster = SimCluster::start(&idx, topo(ctx, ctx.workers, 1)).unwrap();
            let mut thr_row = vec![format!("m={m}")];
            let mut lat_row = vec![format!("m={m}")];
            for &k in &ks {
                let params = QueryParams { k: 10, branch: k, ef: 100, meta_ef: 100.max(k) };
                let rep = drive_cluster(
                    &cluster,
                    &workload,
                    &params,
                    ctx.clients,
                    Duration::from_secs_f64(ctx.seconds),
                );
                thr_row.push(format!("{:.0}", rep.qps));
                lat_row.push(format!("{:.2}", rep.latency.p90_ms()));
            }
            cluster.shutdown();
            thr.row(thr_row);
            lat.row(lat_row);
        }
        println!("\n[Fig 7] {name}: throughput (qps) vs K (expect: down with K)");
        thr.print();
        println!("\n[Fig 8] {name}: P90 latency (ms) vs K (expect: up with K)");
        lat.print();
    }
}

/// Calibrate Pyramid's branch factor to reach a target precision locally.
fn calibrate_branch(
    idx: &PyramidIndex,
    workload: &Workload,
    target: f64,
    ef: usize,
) -> (usize, f64) {
    for branch in [1usize, 2, 3, 4, 5, 6, 8, 10, 12, 16, 20, 32] {
        let params = QueryParams { k: workload.k, branch, ef, meta_ef: 100.max(branch) };
        let nq = workload.queries.len().min(150);
        let results: Vec<Vec<Neighbor>> =
            (0..nq).map(|qi| idx.search(workload.queries.get(qi), &params)).collect();
        let mut hit = 0;
        for (qi, res) in results.iter().enumerate() {
            let gt: std::collections::HashSet<u32> =
                workload.ground_truth[qi].iter().map(|n| n.id).collect();
            hit += res.iter().take(workload.k).filter(|n| gt.contains(&n.id)).count();
        }
        let p = hit as f64 / (nq * workload.k) as f64;
        if p >= target {
            return (branch, p);
        }
    }
    (32, 0.0)
}

/// Fig 9: Pyramid vs HNSW-naive vs FLANN(KD-forest) at matched precision.
fn fig9(ctx: &Ctx) {
    println!("\n=== Fig 9: system comparison at ~90% precision ===");
    for (name, spec) in [("Deep", deep(ctx)), ("SIFT", sift(ctx))] {
        let data = spec.generate();
        let queries = spec.queries(500);
        let workload = Workload::new(data.clone(), queries, Metric::L2, 10);
        let mut t = TablePrinter::new(&["system", "config", "qps", "precision", "p90 ms"]);

        // Pyramid: calibrate branch to ~0.9 precision.
        let idx =
            PyramidIndex::build(&data, Metric::L2, &index_cfg(400, ctx.workers, data.len())).unwrap();
        let (branch, _) = calibrate_branch(&idx, &workload, 0.90, 100);
        let cluster = SimCluster::start(&idx, topo(ctx, ctx.workers, 1)).unwrap();
        let params = QueryParams { k: 10, branch, ef: 100, meta_ef: 100 };
        let rep =
            drive_cluster(&cluster, &workload, &params, ctx.clients, Duration::from_secs_f64(ctx.seconds));
        cluster.shutdown();
        let pyramid_qps = rep.qps;
        t.row(vec![
            "Pyramid".into(),
            format!("K={branch}, l=100"),
            format!("{:.0}", rep.qps),
            format!("{:.3}", rep.precision),
            format!("{:.2}", rep.latency.p90_ms()),
        ]);

        // HNSW-naive: all partitions, same graph params.
        let naive = NaiveIndex::build(&data, Metric::L2, ctx.workers, HnswParams::default(), 3).unwrap();
        let ncluster = naive.serve(topo(ctx, ctx.workers, 1), None).unwrap();
        let nparams = QueryParams { k: 10, branch: 1, ef: 100, meta_ef: 1 };
        let nrep =
            drive_cluster(&ncluster, &workload, &nparams, ctx.clients, Duration::from_secs_f64(ctx.seconds));
        ncluster.shutdown();
        t.row(vec![
            "HNSW-naive".into(),
            "all workers, l=100".into(),
            format!("{:.0}", nrep.qps),
            format!("{:.3}", nrep.precision),
            format!("{:.2}", nrep.latency.p90_ms()),
        ]);

        // FLANN substitute: KD-forest, recommended settings (4 trees,
        // checks budget = 2048).
        let kd = DistributedKdForest::build(&data, ctx.workers, KdForestParams::default()).unwrap();
        let kcluster = kd.serve(topo(ctx, ctx.workers, 1)).unwrap();
        let kparams = QueryParams { k: 10, branch: 1, ef: 2048, meta_ef: 1 };
        let krep =
            drive_cluster(&kcluster, &workload, &kparams, ctx.clients, Duration::from_secs_f64(ctx.seconds));
        kcluster.shutdown();
        t.row(vec![
            "FLANN (KD-forest)".into(),
            "4 trees, checks=2048".into(),
            format!("{:.0}", krep.qps),
            format!("{:.3}", krep.precision),
            format!("{:.2}", krep.latency.p90_ms()),
        ]);

        println!("\n[Fig 9] {name} (expect: Pyramid > 2x naive qps at matched precision; both >> KD)");
        t.print();
        println!(
            "Pyramid/naive throughput ratio: {:.2}x (paper: >2x)",
            pyramid_qps / nrep.qps.max(1e-9)
        );
    }
}

/// Fig 10: MIPS — Pyramid (Alg 5) vs HNSW-naive on tiny-like.
fn fig10(ctx: &Ctx) {
    println!("\n=== Fig 10: MIPS on tiny-like (Algorithm 5 vs naive) ===");
    let spec = tiny(ctx);
    let data = spec.generate();
    let queries = spec.queries(400);
    let workload = Workload::new(data.clone(), queries, Metric::Ip, 10);
    let n = data.len();
    let mut t = TablePrinter::new(&["system", "branch K", "qps", "precision", "stored items"]);

    let r = (n / 100).clamp(20, 300); // keep m*r a few % of n
    let cfg = IndexConfig { mips_replication: r, ..index_cfg(100, ctx.workers, n) };
    let idx = PyramidIndex::build(&data, Metric::Ip, &cfg).unwrap();
    for branch in [1usize, 2, 4] {
        let cluster = SimCluster::start(&idx, topo(ctx, ctx.workers, 1)).unwrap();
        let params = QueryParams { k: 10, branch, ef: 100, meta_ef: 100 };
        let rep =
            drive_cluster(&cluster, &workload, &params, ctx.clients, Duration::from_secs_f64(ctx.seconds));
        cluster.shutdown();
        t.row(vec![
            format!("Pyramid (r={r})"),
            branch.to_string(),
            format!("{:.0}", rep.qps),
            format!("{:.3}", rep.precision),
            format!(
                "{} (+{:.1}%)",
                idx.stored_items(),
                100.0 * (idx.stored_items() - n) as f64 / n as f64
            ),
        ]);
    }

    let naive = NaiveIndex::build(&data, Metric::Ip, ctx.workers, HnswParams::default(), 3).unwrap();
    let ncluster = naive.serve(topo(ctx, ctx.workers, 1), None).unwrap();
    let nparams = QueryParams { k: 10, branch: 1, ef: 100, meta_ef: 1 };
    let nrep =
        drive_cluster(&ncluster, &workload, &nparams, ctx.clients, Duration::from_secs_f64(ctx.seconds));
    ncluster.shutdown();
    t.row(vec![
        "HNSW-naive".into(),
        "all".into(),
        format!("{:.0}", nrep.qps),
        format!("{:.3}", nrep.precision),
        format!("{n} (+0%)"),
    ]);
    println!("(expect: Pyramid qps >> naive at similar precision; small storage overhead)");
    t.print();
}

/// Fig 11: scalability — 5 vs 10 workers at matched precision targets.
fn fig11(ctx: &Ctx) {
    println!("\n=== Fig 11: scalability (5 vs 10 workers, matched precision) ===");
    let spec = sift(ctx);
    let data = spec.generate();
    let queries = spec.queries(400);
    let workload = Workload::new(data.clone(), queries, Metric::L2, 10);
    let mut t = TablePrinter::new(&["precision target", "workers", "branch K", "qps", "precision"]);
    let mut qps_at: std::collections::HashMap<(usize, usize), f64> = std::collections::HashMap::new();
    for &target_pct in &[80usize, 90] {
        let target = target_pct as f64 / 100.0;
        for &workers in &[5usize, 10] {
            let idx =
                PyramidIndex::build(&data, Metric::L2, &index_cfg(400, workers, data.len())).unwrap();
            let (branch, _) = calibrate_branch(&idx, &workload, target, 100);
            let cluster = SimCluster::start(&idx, topo(ctx, workers, 1)).unwrap();
            let params = QueryParams { k: 10, branch, ef: 100, meta_ef: 100 };
            let rep = drive_cluster(
                &cluster,
                &workload,
                &params,
                ctx.clients,
                Duration::from_secs_f64(ctx.seconds),
            );
            cluster.shutdown();
            qps_at.insert((target_pct, workers), rep.qps);
            t.row(vec![
                format!("{target_pct}%"),
                workers.to_string(),
                branch.to_string(),
                format!("{:.0}", rep.qps),
                format!("{:.3}", rep.precision),
            ]);
        }
    }
    t.print();
    for &p in &[80usize, 90] {
        let r = qps_at[&(p, 10)] / qps_at[&(p, 5)].max(1e-9);
        println!("10-worker/5-worker throughput ratio at {p}%: {r:.2}x (paper: 1.78x / 1.59x)");
    }
}

/// Fig 12: straggler — throughput vs CPU share of one host, 2x
/// replication, ~70% load.
fn fig12(ctx: &Ctx) {
    println!("\n=== Fig 12: straggler mitigation (2 replicas, ~70% load) ===");
    let spec = sift(ctx);
    let data = spec.generate();
    let queries = spec.queries(400);
    let workload = Workload::new(data.clone(), queries, Metric::L2, 10);
    let workers = 5usize.min(ctx.workers);
    let idx = PyramidIndex::build(&data, Metric::L2, &index_cfg(200, workers, data.len())).unwrap();
    let cluster = SimCluster::start(&idx, topo(ctx, workers, 2)).unwrap();
    let params = QueryParams { k: 10, branch: 2, ef: 100, meta_ef: 100 };
    // Measure peak with full clients, then run at ~70% load.
    let peak =
        drive_cluster(&cluster, &workload, &params, ctx.clients, Duration::from_secs_f64(ctx.seconds));
    let load_clients = ((ctx.clients as f64) * 0.7).ceil() as usize;
    let mut t = TablePrinter::new(&["CPU share of host 0", "qps", "vs unthrottled"]);
    let mut base = 0.0f64;
    for &share in &[100u32, 70, 50, 30, 10] {
        cluster.set_cpu_share(0, share);
        // Let the queue rebalance settle.
        std::thread::sleep(Duration::from_millis(300));
        let rep = drive_cluster(
            &cluster,
            &workload,
            &params,
            load_clients,
            Duration::from_secs_f64(ctx.seconds),
        );
        if share == 100 {
            base = rep.qps;
        }
        t.row(vec![
            format!("{share}%"),
            format!("{:.0}", rep.qps),
            format!("{:.2}", rep.qps / base.max(1e-9)),
        ]);
    }
    cluster.set_cpu_share(0, 100);
    cluster.shutdown();
    println!("peak (100% load clients): {:.0} qps; running at ~70% load", peak.qps);
    println!("(expect: flat until ~30% share, significant drop only at 10% — paper Fig 12)");
    t.print();
}

/// Fig 13: throughput timeline under kill + rejoin.
fn fig13(ctx: &Ctx) {
    println!("\n=== Fig 13: failure timeline (kill at T/3, rejoin at 2T/3) ===");
    let spec = sift(ctx);
    let data = spec.generate();
    let queries = spec.queries(400);
    let workload = Workload::new(data.clone(), queries, Metric::L2, 10);
    let workers = 5usize.min(ctx.workers);
    let idx = PyramidIndex::build(&data, Metric::L2, &index_cfg(200, workers, data.len())).unwrap();
    let cluster = SimCluster::start(&idx, topo(ctx, workers, 2)).unwrap();
    let params = QueryParams { k: 10, branch: 2, ef: 100, meta_ef: 100 };

    let total = Duration::from_secs_f64(ctx.seconds * 4.0);
    let window = Duration::from_millis(250);
    let nbuckets = (total.as_secs_f64() / window.as_secs_f64()) as usize + 2;
    let buckets: Vec<std::sync::atomic::AtomicUsize> =
        (0..nbuckets).map(|_| std::sync::atomic::AtomicUsize::new(0)).collect();
    let stop = std::sync::atomic::AtomicBool::new(false);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..ctx.clients {
            let cluster = &cluster;
            let workload = &workload;
            let stop = &stop;
            let buckets = &buckets;
            let params = &params;
            s.spawn(move || {
                let mut qi = c;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    if cluster
                        .execute(workload.queries.get(qi % workload.queries.len()), params)
                        .is_ok()
                    {
                        let idx = (t0.elapsed().as_secs_f64() / window.as_secs_f64()) as usize;
                        if let Some(b) = buckets.get(idx) {
                            b.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                    qi += ctx.clients;
                }
            });
        }
        s.spawn(|| {
            std::thread::sleep(total.mul_f64(1.0 / 3.0));
            eprintln!("[fig13] t={:.1}s KILL host 0", t0.elapsed().as_secs_f64());
            cluster.kill_host(0);
            std::thread::sleep(total.mul_f64(1.0 / 3.0));
            eprintln!("[fig13] t={:.1}s host 0 rejoins", t0.elapsed().as_secs_f64());
            cluster.restart_host(0);
            std::thread::sleep(total.mul_f64(1.0 / 3.0));
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
        });
    });
    let max = buckets
        .iter()
        .map(|b| b.load(std::sync::atomic::Ordering::Relaxed))
        .max()
        .unwrap_or(1)
        .max(1);
    println!(
        "time(s)  qps      |histogram (kill at {:.1}s, rejoin at {:.1}s)",
        total.as_secs_f64() / 3.0,
        2.0 * total.as_secs_f64() / 3.0
    );
    for (i, b) in buckets.iter().enumerate() {
        let at = i as f64 * window.as_secs_f64();
        if at > total.as_secs_f64() {
            break;
        }
        let v = b.load(std::sync::atomic::Ordering::Relaxed);
        println!(
            "{:>6.2} {:>8.0}  |{}",
            at,
            v as f64 / window.as_secs_f64(),
            "#".repeat(v * 50 / max)
        );
    }
    println!("(expect: dip at kill, dip at rejoin rebalance, recovery after — paper Fig 13)");
    cluster.shutdown();
}

/// §V-C text: index build time breakdown, Pyramid vs naive vs KD-forest.
fn table_build(ctx: &Ctx) {
    println!("\n=== Table: index build time breakdown (paper §V-C text) ===");
    let spec = deep(ctx);
    let data = spec.generate();
    let mut t = TablePrinter::new(&["system", "total", "kmeans+meta", "partition+assign", "sub-index build"]);
    let idx = PyramidIndex::build(&data, Metric::L2, &index_cfg(400, ctx.workers, data.len())).unwrap();
    let r = &idx.report;
    t.row(vec![
        "Pyramid".into(),
        format!("{:.1?}", r.total()),
        format!("{:.1?}", r.sample_kmeans + r.meta_build),
        format!("{:.1?}", r.partition + r.assign),
        format!("{:.1?}", r.sub_build),
    ]);
    let naive = NaiveIndex::build(&data, Metric::L2, ctx.workers, HnswParams::default(), 3).unwrap();
    t.row(vec![
        "HNSW-naive".into(),
        format!("{:.1?}", naive.build_time),
        "-".into(),
        "(random shuffle)".into(),
        format!("{:.1?}", naive.build_time),
    ]);
    let kd = DistributedKdForest::build(&data, ctx.workers, KdForestParams::default()).unwrap();
    t.row(vec![
        "FLANN (KD-forest)".into(),
        format!("{:.1?}", kd.build_time),
        "-".into(),
        "(random shuffle)".into(),
        format!("{:.1?}", kd.build_time),
    ]);
    t.print();
    println!("(expect: Pyramid slowest [meta+assign overhead], KD-forest fastest — paper: 162min/53min/38s)");
}
