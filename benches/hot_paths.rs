//! Hot-path micro-benchmarks (hand-rolled harness; criterion is not
//! available offline). Used by the §Perf optimization pass: run before and
//! after each change and record deltas in EXPERIMENTS.md.
//!
//!     cargo bench --bench hot_paths [-- <filter>]

use pyramid::broker::{Broker, BrokerConfig};
use pyramid::dataset::SyntheticSpec;
use pyramid::hnsw::{Hnsw, HnswParams};
use pyramid::metric::{dot_unrolled, l2_sq_unrolled, Metric};
use pyramid::runtime::{default_artifacts_dir, BatchScorer, NativeScorer, PjrtScorer};
use pyramid::types::{merge_topk, Neighbor};
use std::time::{Duration, Instant};

/// Time `f` for ~`target` wall time after warmup; print ns/op + ops/s.
fn bench<F: FnMut() -> u64>(name: &str, mut f: F) {
    // Warmup.
    let mut units = 0u64;
    for _ in 0..3 {
        units = units.max(f());
    }
    let target = Duration::from_millis(400);
    let t0 = Instant::now();
    let mut iters = 0u64;
    let mut total_units = 0u64;
    while t0.elapsed() < target {
        total_units += f();
        iters += 1;
    }
    let elapsed = t0.elapsed();
    let ns_per_unit = elapsed.as_nanos() as f64 / total_units.max(1) as f64;
    println!(
        "{name:<44} {:>10.1} ns/op {:>14.0} ops/s   ({iters} iters)",
        ns_per_unit,
        1e9 / ns_per_unit
    );
}

fn main() {
    let filter: Option<String> = std::env::args().skip(1).find(|a| a != "--bench" && !a.starts_with("--"));
    let run = |name: &str| filter.as_deref().map(|f| name.contains(f)).unwrap_or(true);
    println!("== pyramid hot-path micro-benchmarks ==");

    // --- metric kernels ----------------------------------------------------
    for d in [96usize, 128, 384] {
        let a: Vec<f32> = (0..d).map(|i| (i as f32) * 0.01).collect();
        let b: Vec<f32> = (0..d).map(|i| (i as f32) * -0.02).collect();
        if run("metric/dot") {
            bench(&format!("metric/dot d={d}"), || {
                let mut acc = 0.0;
                for _ in 0..1024 {
                    acc += dot_unrolled(std::hint::black_box(&a), std::hint::black_box(&b));
                }
                std::hint::black_box(acc);
                1024
            });
        }
        if run("metric/l2") {
            bench(&format!("metric/l2 d={d}"), || {
                let mut acc = 0.0;
                for _ in 0..1024 {
                    acc += l2_sq_unrolled(std::hint::black_box(&a), std::hint::black_box(&b));
                }
                std::hint::black_box(acc);
                1024
            });
        }
    }

    // --- HNSW search (the per-executor hot loop) ----------------------------
    if run("hnsw") {
        let data = SyntheticSpec::deep_like(50_000, 96, 3).generate();
        let queries = SyntheticSpec::deep_like(50_000, 96, 3).queries(256);
        let h = Hnsw::build(data, Metric::L2, HnswParams::default()).unwrap();
        for ef in [50usize, 100, 200] {
            let mut qi = 0usize;
            bench(&format!("hnsw/search n=50k d=96 ef={ef}"), || {
                let q = queries.get(qi % queries.len());
                std::hint::black_box(h.search(q, 10, ef));
                qi += 1;
                1
            });
        }
        let (_, stats) = h.search_with_stats(queries.get(0), 10, 100);
        println!("  (ef=100 walk: {} dist evals, {} hops)", stats.dist_evals, stats.hops);
    }

    // --- merge / coordinator path -------------------------------------------
    if run("merge") {
        let partials: Vec<Neighbor> =
            (0..100u32).map(|i| Neighbor::new(i % 60, 1.0 - (i as f32) * 0.01)).collect();
        bench("coordinator/merge_topk 100 -> 10", || {
            std::hint::black_box(merge_topk(std::hint::black_box(partials.clone()), 10));
            1
        });
    }

    // --- broker round trip ---------------------------------------------------
    if run("broker") {
        let b: Broker<u64> = Broker::new(BrokerConfig {
            rebalance_pause: Duration::from_millis(0),
            ..BrokerConfig::default()
        });
        b.create_topic("t");
        let c = b.subscribe("t", "g", 1).unwrap();
        let mut k = 0u64;
        bench("broker/publish+poll+ack roundtrip", || {
            b.publish("t", k, k).unwrap();
            let d = c.poll(Duration::from_millis(100)).unwrap();
            c.ack(&d);
            k += 1;
            1
        });
    }

    // --- rerank: native vs PJRT ----------------------------------------------
    if run("rerank") {
        let cands = SyntheticSpec::deep_like(512, 96, 5).generate();
        let q = SyntheticSpec::deep_like(512, 96, 5).queries(1);
        let ids: Vec<u32> = (0..cands.len() as u32).collect();
        bench("rerank/native 512 cands d=96", || {
            std::hint::black_box(
                NativeScorer.rerank(Metric::L2, q.get(0), cands.raw(), &ids, 10).unwrap(),
            );
            1
        });
        if let Some(dir) = default_artifacts_dir() {
            let pjrt = PjrtScorer::spawn(dir).unwrap();
            bench("rerank/pjrt 512 cands d=96 (AOT Pallas)", || {
                std::hint::black_box(pjrt.rerank(Metric::L2, q.get(0), cands.raw(), &ids, 10).unwrap());
                1
            });
            bench("scores/pjrt block 128x4096 d=96", || {
                let qb = SyntheticSpec::deep_like(128, 96, 9).generate();
                let xb = SyntheticSpec::deep_like(4096, 96, 10).generate();
                std::hint::black_box(
                    pjrt.scores(Metric::L2, qb.raw(), 128, xb.raw(), 4096, 96).unwrap(),
                );
                128 * 4096
            });
        } else {
            println!("rerank/pjrt: SKIP (run `make artifacts`)");
        }
    }

    println!("done.");
}
