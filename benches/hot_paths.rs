//! Hot-path micro-benchmarks (hand-rolled harness; criterion is not
//! available offline). Used by the §Perf optimization pass: run before and
//! after each change and record deltas in EXPERIMENTS.md.
//!
//!     cargo bench --bench hot_paths [-- <filter>] [--smoke] [--json]
//!
//! `--smoke` shrinks the datasets and measurement windows so CI finishes
//! in seconds; `--json` writes every measurement (ns/op, keyed by bench
//! name) to `BENCH_hot_paths.json` via [`BenchRecorder`] so the perf
//! trajectory is recorded run over run.
//!
//! The headline comparisons, all measured on identical graphs and
//! recorded as ratio metrics in the JSON rather than asserted (they are
//! properties of the memory system; shared CI runners are too noisy for a
//! hard threshold — the CI trend-diff step watches them instead):
//!
//! * `hnsw/csr-speedup ef=*` — frozen CSR serving layout (`hnsw/search`)
//!   vs the nested `Vec<Vec>` build form (`hnsw/search-nested`), PR 1.
//! * `hnsw/block-walk-speedup ef=*` — the block-scored bottom-layer walk
//!   (each neighbor block through one `Metric::score_rows` pass) vs the
//!   per-edge baseline (`hnsw/search-per-edge`), PR 2.
//! * `router/batch-speedup b=*` — `Router::route_batch` (shared
//!   visited-pool meta walk for a whole block) vs sequential `route`
//!   calls, PR 2.
//! * `ingest/*` — the streaming write path, PR 4: insert throughput,
//!   search latency under sustained ingest vs idle, and the freshness
//!   lag (insert -> searchable round trip).
//! * `metric/sq8-speedup`, `hnsw/sq8-walk-speedup ef=*`,
//!   `e2e/sq8-recall-delta`, `e2e/sq8-memory-ratio` — the SQ8 quantized
//!   scoring tier, PR 5: integer-kernel block scoring vs f32, the
//!   quantized walk + exact refine vs the f32 walk on the same frozen
//!   graph, and the end-to-end recall cost / memory win.
//! * `load/p99-static-vs-elastic`, `load/controller-reaction-ms`,
//!   `load/hot-partition-qps` — the trace-driven load harness, PR 7:
//!   hot-partition p99 of a static placement over the elasticity
//!   controller's (higher is better), the overload-to-first-action
//!   latency, and the served hot-partition QPS under the controller.
//! * `net/same-rack-gather-p99 ms`, `net/cross-rack-gather-p99 ms`,
//!   `net/hedge-win-ratio` — the transport plane, PR 8: gather tail on a
//!   fat-tree fabric with every host rack-local vs one host per rack,
//!   and the hedged-over-unhedged p99 win when each partition's replicas
//!   sit at asymmetric distances (higher is better).
//! * `obs/trace-overhead-pct`, `obs/scrape-us`,
//!   `obs/walk-hook-overhead-pct` — the telemetry plane, PR 9: end-to-end
//!   query cost with the plane attached vs detached, the cost of one
//!   snapshot-consistent registry scrape, and the profiled vs unprofiled
//!   walk on the same frozen graph. The `-pct` keys regress on an
//!   absolute +2pp widening (percentage points, like the recall deltas —
//!   relative thresholds are meaningless near zero).
//! * `repart/migration-pause-p99 ms`, `repart/recall-delta` — the
//!   self-healing partition plane, PR 10: query p99 while one live
//!   migration ladder (copy -> barrier -> cutover -> retire) runs —
//!   dual-serve must keep the pause invisible — and the
//!   rebuild-minus-migrated recall@10 gap, watched by the trend step's
//!   `recall-delta` rule (+2pp absolute).

use pyramid::bench_harness::BenchRecorder;
use pyramid::broker::{Broker, BrokerConfig};
use pyramid::cluster::SimCluster;
use pyramid::config::{ClusterTopology, IndexConfig, QueryParams};
use pyramid::coordinator::{CoordinatorConfig, HedgeConfig};
use pyramid::dataset::SyntheticSpec;
use pyramid::hnsw::{Hnsw, HnswParams, NestedHnsw};
use pyramid::ingest::IngestConfig;
use pyramid::meta::{PyramidIndex, Router};
use pyramid::metric::{dot, dot_unrolled, l2_sq, l2_sq_unrolled, Metric};
use pyramid::quant::QuantPlane;
use pyramid::runtime::{default_artifacts_dir, BatchScorer, NativeScorer, PjrtScorer};
use pyramid::stats::percentile;
use pyramid::types::{merge_topk, BatchQuery, Neighbor};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Time `f` until `target` wall time after warmup; print and return ns/op.
fn bench_for<F: FnMut() -> u64>(name: &str, target: Duration, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    let t0 = Instant::now();
    let mut iters = 0u64;
    let mut total_units = 0u64;
    while t0.elapsed() < target {
        total_units += f();
        iters += 1;
    }
    let elapsed = t0.elapsed();
    let ns_per_unit = elapsed.as_nanos() as f64 / total_units.max(1) as f64;
    println!(
        "{name:<44} {:>10.1} ns/op {:>14.0} ops/s   ({iters} iters)",
        ns_per_unit,
        1e9 / ns_per_unit
    );
    ns_per_unit
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let emit_json = args.iter().any(|a| a == "--json");
    let filter: Option<String> =
        args.into_iter().find(|a| a != "--bench" && !a.starts_with("--"));
    let run = |name: &str| filter.as_deref().map(|f| name.contains(f)).unwrap_or(true);
    let target =
        if smoke { Duration::from_millis(80) } else { Duration::from_millis(400) };
    let mut rec = BenchRecorder::new();
    println!("== pyramid hot-path micro-benchmarks{} ==", if smoke { " (smoke)" } else { "" });

    let mut bench = |rec: &mut BenchRecorder, name: &str, f: &mut dyn FnMut() -> u64| {
        let ns = bench_for(name, target, f);
        rec.record(name, ns);
        ns
    };

    // --- metric kernels: dispatched SIMD vs unrolled scalar ----------------
    for d in [96usize, 128, 384] {
        let a: Vec<f32> = (0..d).map(|i| (i as f32) * 0.01).collect();
        let b: Vec<f32> = (0..d).map(|i| (i as f32) * -0.02).collect();
        if run("metric/dot") {
            bench(&mut rec, &format!("metric/dot d={d}"), &mut || {
                let mut acc = 0.0;
                for _ in 0..1024 {
                    acc += dot(std::hint::black_box(&a), std::hint::black_box(&b));
                }
                std::hint::black_box(acc);
                1024
            });
            bench(&mut rec, &format!("metric/dot-scalar d={d}"), &mut || {
                let mut acc = 0.0;
                for _ in 0..1024 {
                    acc += dot_unrolled(std::hint::black_box(&a), std::hint::black_box(&b));
                }
                std::hint::black_box(acc);
                1024
            });
        }
        if run("metric/l2") {
            bench(&mut rec, &format!("metric/l2 d={d}"), &mut || {
                let mut acc = 0.0;
                for _ in 0..1024 {
                    acc += l2_sq(std::hint::black_box(&a), std::hint::black_box(&b));
                }
                std::hint::black_box(acc);
                1024
            });
            bench(&mut rec, &format!("metric/l2-scalar d={d}"), &mut || {
                let mut acc = 0.0;
                for _ in 0..1024 {
                    acc += l2_sq_unrolled(std::hint::black_box(&a), std::hint::black_box(&b));
                }
                std::hint::black_box(acc);
                1024
            });
        }
    }

    // --- HNSW search: frozen CSR vs nested-vec baseline ---------------------
    if run("hnsw") {
        let n = if smoke { 10_000 } else { 50_000 };
        let data = SyntheticSpec::deep_like(n, 96, 3).generate();
        let queries = SyntheticSpec::deep_like(n, 96, 3).queries(256);
        let nested = NestedHnsw::build(data, Metric::L2, HnswParams::default()).unwrap();
        let mut nested_ns = std::collections::HashMap::new();
        for ef in [50usize, 100, 200] {
            let mut qi = 0usize;
            let ns = bench(&mut rec, &format!("hnsw/search-nested n={n} ef={ef}"), &mut || {
                let q = queries.get(qi % queries.len());
                std::hint::black_box(nested.search(q, 10, ef));
                qi += 1;
                1
            });
            nested_ns.insert(ef, ns);
        }
        let h = nested.freeze();
        let mut frozen_ns = std::collections::HashMap::new();
        for ef in [50usize, 100, 200] {
            let mut qi = 0usize;
            let ns = bench(&mut rec, &format!("hnsw/search n={n} ef={ef}"), &mut || {
                let q = queries.get(qi % queries.len());
                std::hint::black_box(h.search(q, 10, ef));
                qi += 1;
                1
            });
            frozen_ns.insert(ef, ns);
            let speedup = nested_ns[&ef] / ns;
            rec.record(&format!("hnsw/csr-speedup ef={ef}"), speedup);
            println!("  -> frozen CSR speedup vs nested @ ef={ef}: {speedup:.2}x");
        }
        // Per-edge scoring baseline on the same frozen graph: the default
        // walk block-scores each gathered neighbor set through one
        // `score_rows` pass; this measures what that buys over individual
        // `Metric::score` calls (identical results, pinned by tests).
        for ef in [50usize, 100, 200] {
            let mut qi = 0usize;
            let ns = bench(&mut rec, &format!("hnsw/search-per-edge n={n} ef={ef}"), &mut || {
                let q = queries.get(qi % queries.len());
                std::hint::black_box(h.search_per_edge(q, 10, ef));
                qi += 1;
                1
            });
            let speedup = ns / frozen_ns[&ef];
            rec.record(&format!("hnsw/block-walk-speedup ef={ef}"), speedup);
            println!("  -> block-scored walk speedup vs per-edge @ ef={ef}: {speedup:.2}x");
        }
        let (_, stats) = h.search_with_stats(queries.get(0), 10, 100);
        println!("  (ef=100 walk: {} dist evals, {} hops)", stats.dist_evals, stats.hops);

        // Batched bottom-layer pass (the executor drain path).
        if run("hnsw/batch") {
            let mut qi = 0usize;
            bench(&mut rec, &format!("hnsw/search-batch8 n={n} ef=100"), &mut || {
                let batch: Vec<BatchQuery<'_>> = (0..8)
                    .map(|j| BatchQuery { query: queries.get((qi + j) % queries.len()), k: 10, ef: 100 })
                    .collect();
                std::hint::black_box(h.search_batch(&batch, &NativeScorer));
                qi += 8;
                8
            });
        }
    }

    // --- SQ8 quantized scoring tier (PR 5) ----------------------------------
    // Three facets. (1) Raw block scoring: one query against a dense row
    // block through the f32 kernels vs the integer kernels over codes —
    // the bandwidth story isolated from the graph. (2) The walk: f32 vs
    // quantized+refined search on the SAME frozen graph. (3) End-to-end
    // recall delta + memory ratio on a quantized PyramidIndex (recorded
    // as numbers, not timings — the trend step watches the delta).
    if run("sq8") || run("metric/sq8") {
        let d = 96usize;
        let n = if smoke { 16_384 } else { 65_536 };
        let data = SyntheticSpec::deep_like(n, d, 41).generate();
        let q = SyntheticSpec::deep_like(n, d, 41).queries(1);
        let plane = QuantPlane::encode_dataset(&data, 0);
        let view = plane.view();
        let pq = plane.codec().prepare_query(q.get(0));
        let ids: Vec<u32> = (0..n as u32).collect();
        let mut out: Vec<f32> = Vec::with_capacity(n);
        let f32_ns = bench(&mut rec, &format!("metric/score-block f32 n={n} d={d}"), &mut || {
            Metric::L2.score_many(q.get(0), data.raw(), d, &mut out);
            std::hint::black_box(out.last().copied());
            n as u64
        });
        let sq8_ns = bench(&mut rec, &format!("metric/score-block sq8 n={n} d={d}"), &mut || {
            view.score_ids(Metric::L2, &pq, &ids, &mut out);
            std::hint::black_box(out.last().copied());
            n as u64
        });
        let speedup = f32_ns / sq8_ns;
        rec.record("metric/sq8-speedup", speedup);
        println!("  -> sq8 block-scoring speedup vs f32 @ d={d}: {speedup:.2}x");
    }

    if run("sq8") || run("hnsw/sq8") {
        let n = if smoke { 10_000 } else { 50_000 };
        let data = SyntheticSpec::deep_like(n, 96, 3).generate();
        let queries = SyntheticSpec::deep_like(n, 96, 3).queries(256);
        // One graph, both tiers: search_f32 ignores the plane, search
        // runs the quantized walk + exact top-refine_k re-rank.
        let h = Hnsw::build_sq8(data, Metric::L2, HnswParams::default(), 0).unwrap();
        for ef in [50usize, 100, 200] {
            let mut qi = 0usize;
            let f32_ns = bench(&mut rec, &format!("hnsw/search-f32 n={n} ef={ef}"), &mut || {
                let q = queries.get(qi % queries.len());
                std::hint::black_box(h.search_f32(q, 10, ef));
                qi += 1;
                1
            });
            let mut qj = 0usize;
            let sq8_ns = bench(&mut rec, &format!("hnsw/search-sq8 n={n} ef={ef}"), &mut || {
                let q = queries.get(qj % queries.len());
                std::hint::black_box(h.search(q, 10, ef));
                qj += 1;
                1
            });
            let speedup = f32_ns / sq8_ns;
            rec.record(&format!("hnsw/sq8-walk-speedup ef={ef}"), speedup);
            println!("  -> sq8 walk speedup vs f32 @ ef={ef}: {speedup:.2}x");
        }
        println!(
            "  (plane: {} KiB vs {} KiB f32 rows)",
            h.sq8_bytes().unwrap() / 1024,
            h.len() * 96 * 4 / 1024
        );
    }

    if run("sq8") || run("e2e/sq8") {
        let n = if smoke { 4_000 } else { 8_000 };
        let mut spec = SyntheticSpec::deep_like(n, 24, 55);
        spec.clusters = 48;
        let data = spec.generate();
        let queries = spec.queries(if smoke { 24 } else { 48 });
        let base_cfg = IndexConfig {
            sample: n / 4,
            meta_size: 48,
            partitions: 6,
            ..IndexConfig::default()
        };
        let qcfg = IndexConfig { quantize: true, refine_k: 40, ..base_cfg };
        let f32_idx = PyramidIndex::build(&data, Metric::L2, &base_cfg).expect("f32 e2e index");
        let sq8_idx = PyramidIndex::build(&data, Metric::L2, &qcfg).expect("sq8 e2e index");
        let params = QueryParams { k: 10, branch: 4, ef: 100, meta_ef: 100 };
        let recall = |idx: &PyramidIndex| -> f64 {
            let mut hits = 0usize;
            for qi in 0..queries.len() {
                let q = queries.get(qi);
                let gt: std::collections::HashSet<u32> =
                    pyramid::bruteforce::search(&data, q, Metric::L2, 10)
                        .iter()
                        .map(|nb| nb.id)
                        .collect();
                hits += idx.search(q, &params).iter().filter(|nb| gt.contains(&nb.id)).count();
            }
            hits as f64 / (queries.len() * 10) as f64
        };
        let (r_f32, r_sq8) = (recall(&f32_idx), recall(&sq8_idx));
        let rows: usize = sq8_idx.subs.iter().map(|s| s.len() * s.dim() * 4).sum();
        let planes: usize = sq8_idx.subs.iter().map(|s| s.sq8_bytes().unwrap_or(0)).sum();
        rec.record("e2e/sq8-recall-delta", r_f32 - r_sq8);
        rec.record("e2e/sq8-memory-ratio", rows as f64 / planes.max(1) as f64);
        println!(
            "sq8 e2e: recall@10 f32 {r_f32:.3} vs sq8 {r_sq8:.3} (delta {:+.3}); \
             vector plane {rows} B -> code plane {planes} B ({:.2}x)",
            r_f32 - r_sq8,
            rows as f64 / planes.max(1) as f64
        );
    }

    // --- meta-HNSW routing: batched vs sequential ---------------------------
    if run("router") {
        let m = if smoke { 2_000 } else { 10_000 };
        let parts = 16usize;
        let centers = SyntheticSpec::deep_like(m, 96, 11).generate();
        let meta = Hnsw::build(centers, Metric::L2, HnswParams::default()).unwrap();
        // A synthetic balanced partition map is enough: routing cost is
        // the meta walk, not the id lookup.
        let partition: Vec<u32> = (0..m as u32).map(|u| u % parts as u32).collect();
        let router = Router::new(Arc::new(meta), Arc::new(partition), parts);
        let queries = SyntheticSpec::deep_like(m, 96, 12).queries(256);
        const B: usize = 32;
        let mut qi = 0usize;
        let seq_ns = bench(&mut rec, &format!("router/route x{B} sequential"), &mut || {
            for j in 0..B {
                std::hint::black_box(router.route(queries.get((qi + j) % queries.len()), 4, 100));
            }
            qi += B;
            B as u64
        });
        let mut qj = 0usize;
        let batch_ns = bench(&mut rec, &format!("router/route_batch b={B}"), &mut || {
            let block: Vec<&[f32]> =
                (0..B).map(|j| queries.get((qj + j) % queries.len())).collect();
            std::hint::black_box(router.route_batch(&block, 4, 100));
            qj += B;
            B as u64
        });
        let speedup = seq_ns / batch_ns;
        rec.record(&format!("router/batch-speedup b={B}"), speedup);
        println!("  -> batched routing speedup vs sequential @ b={B}: {speedup:.2}x");
    }

    // --- coordinator: hedged dispatch vs straggler tail ---------------------
    // A replicated cluster with one host throttled to 10% CPU (the paper's
    // Fig 12 straggler). `coord/hedge-speedup` is the unhedged-p99 /
    // hedged-p99 ratio on the identical workload — the latency the hedge
    // timer buys back. Wall-clock per-query percentiles, not ns/op.
    if run("coord") {
        let n = if smoke { 2_000 } else { 4_000 };
        let data = SyntheticSpec::deep_like(n, 16, 7).generate();
        let queries = SyntheticSpec::deep_like(n, 16, 7).queries(64);
        let cfg =
            IndexConfig { sample: n / 4, meta_size: 32, partitions: 4, ..IndexConfig::default() };
        let idx = PyramidIndex::build(&data, Metric::L2, &cfg).expect("build bench index");
        let params = QueryParams { k: 10, branch: 4, ef: 100, meta_ef: 100 };
        let rounds = if smoke { 2 } else { 4 };
        let measure = |hedge: HedgeConfig| -> (f64, f64) {
            let topo = ClusterTopology {
                workers: 4,
                replicas: 2,
                coordinators: 2,
                net_latency_us: 500,
                rebalance_ms: 100,
                executor_batch: 8,
                ..ClusterTopology::default()
            };
            let coord_cfg = CoordinatorConfig { hedge, ..CoordinatorConfig::default() };
            let cluster =
                SimCluster::start_with(&idx, topo, None, coord_cfg).expect("start bench cluster");
            // Warm-up arms the hedge window on healthy latencies.
            for qi in 0..queries.len() {
                let _ = cluster.execute(queries.get(qi), &params);
            }
            cluster.set_cpu_share(0, 10);
            let mut ms = Vec::new();
            for _ in 0..rounds {
                for qi in 0..queries.len() {
                    let t0 = Instant::now();
                    let _ = cluster.execute(queries.get(qi), &params);
                    ms.push(t0.elapsed().as_secs_f64() * 1e3);
                }
            }
            cluster.shutdown();
            (percentile(&ms, 50.0), percentile(&ms, 99.0))
        };
        let (p50_u, p99_u) = measure(HedgeConfig::disabled());
        let (p50_h, p99_h) = measure(HedgeConfig::default());
        rec.record("coord/straggler-p50-unhedged ms", p50_u);
        rec.record("coord/straggler-p99-unhedged ms", p99_u);
        rec.record("coord/straggler-p50-hedged ms", p50_h);
        rec.record("coord/straggler-p99-hedged ms", p99_h);
        let speedup = p99_u / p99_h.max(1e-9);
        rec.record("coord/hedge-speedup", speedup);
        println!(
            "coordinator straggler drill: unhedged p50/p99 {p50_u:.2}/{p99_u:.2} ms, \
             hedged {p50_h:.2}/{p99_h:.2} ms"
        );
        println!("  -> hedged p99 speedup vs unhedged: {speedup:.2}x");
    }

    // --- streaming ingest: write path + search-under-ingest -----------------
    // A writable cluster (PR 4). Three recorded facets: raw write-path
    // throughput (route + sequence-numbered log publish per insert —
    // consumption is asynchronous), query latency while a writer streams
    // at full tilt (the paper-style serving SLO under churn), and the
    // freshness lag (insert -> searchable round trip, bounded by one
    // executor poll cycle). Wall-clock percentiles like the coord drill.
    if run("ingest") {
        let n = if smoke { 2_000 } else { 8_000 };
        let data = SyntheticSpec::deep_like(n, 16, 21).generate();
        let queries = SyntheticSpec::deep_like(n, 16, 21).queries(64);
        let extra = SyntheticSpec::deep_like(n, 16, 22).generate();
        let cfg =
            IndexConfig { sample: n / 4, meta_size: 32, partitions: 4, ..IndexConfig::default() };
        let idx = PyramidIndex::build(&data, Metric::L2, &cfg).expect("build ingest bench index");
        let topo = ClusterTopology {
            workers: 4,
            replicas: 1,
            coordinators: 2,
            net_latency_us: 0,
            rebalance_ms: 100,
            executor_batch: 8,
            ..ClusterTopology::default()
        };
        let cluster = SimCluster::start_ingesting(
            &idx,
            topo,
            IngestConfig::default(),
            CoordinatorConfig::default(),
        )
        .expect("start ingest bench cluster");
        let params = QueryParams { k: 10, branch: 2, ef: 100, meta_ef: 100 };
        // Warm the read path (group assignments, latency windows).
        for qi in 0..queries.len() {
            let _ = cluster.execute(queries.get(qi), &params);
        }

        // Write-path throughput: coordinator-side cost per accepted
        // insert. Fixed count rather than a wall-clock window — the log
        // must stay drainable within the bench, and the consumption side
        // (delta growth + background re-freezes) is deliberately part of
        // the cluster the later facets measure.
        let count = if smoke { 1_000 } else { 5_000 };
        let t0 = Instant::now();
        for i in 0..count {
            cluster.insert(extra.get(i % extra.len())).expect("insert");
        }
        let ins_ns = t0.elapsed().as_nanos() as f64 / count as f64;
        rec.record("ingest/insert-throughput", ins_ns);
        println!(
            "{:<44} {:>10.1} ns/op {:>14.0} ops/s   ({count} inserts)",
            "ingest/insert-throughput",
            ins_ns,
            1e9 / ins_ns
        );
        assert!(
            cluster.wait_ingest_idle(Duration::from_secs(60)),
            "ingest bench: replicas never drained the update log"
        );

        // Search-under-ingest: per-query wall latency while one writer
        // streams continuously (~500 inserts/s), vs the idle read path.
        let mut idle_ms = Vec::new();
        for round in 0..(if smoke { 2 } else { 4 }) {
            for qi in 0..queries.len() {
                let t0 = Instant::now();
                let _ = cluster.execute(queries.get((qi + round) % queries.len()), &params);
                idle_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            }
        }
        let stop = std::sync::atomic::AtomicBool::new(false);
        let mut under_ms = Vec::new();
        std::thread::scope(|s| {
            s.spawn(|| {
                let mut j = 0usize;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let _ = cluster.insert(extra.get(j % extra.len()));
                    j += 1;
                    std::thread::sleep(Duration::from_millis(2));
                }
            });
            for round in 0..(if smoke { 2 } else { 4 }) {
                for qi in 0..queries.len() {
                    let t0 = Instant::now();
                    let _ = cluster.execute(queries.get((qi + round) % queries.len()), &params);
                    under_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                }
            }
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
        });
        rec.record("ingest/search-idle-p50 ms", percentile(&idle_ms, 50.0));
        rec.record("ingest/search-under-ingest-p50 ms", percentile(&under_ms, 50.0));
        rec.record("ingest/search-under-ingest-p99 ms", percentile(&under_ms, 99.0));
        println!(
            "ingest drill: idle p50 {:.2} ms, under-ingest p50/p99 {:.2}/{:.2} ms",
            percentile(&idle_ms, 50.0),
            percentile(&under_ms, 50.0),
            percentile(&under_ms, 99.0)
        );

        // Freshness lag: insert -> top-1-searchable round trip. Probes
        // that never become searchable are excluded and reported — a
        // timeout is a failure, not a 5000ms lag sample.
        let mut lags_ms = Vec::new();
        let mut timed_out = 0usize;
        for j in 0..(if smoke { 5 } else { 20 }) {
            let v: Vec<f32> =
                extra.get(j).iter().map(|x| x + 2.0 + j as f32 * 1e-3).collect();
            let t0 = Instant::now();
            let id = cluster.insert(&v).expect("freshness insert");
            let mut found = false;
            while !found && t0.elapsed() < Duration::from_secs(5) {
                found = cluster
                    .execute(&v, &params)
                    .ok()
                    .and_then(|r| r.first().map(|nb| nb.id))
                    == Some(id);
            }
            if found {
                lags_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            } else {
                timed_out += 1;
            }
        }
        if timed_out > 0 {
            println!("  WARN: {timed_out} freshness probes timed out (excluded from the key)");
        }
        if !lags_ms.is_empty() {
            rec.record("ingest/freshness-lag-p50 ms", percentile(&lags_ms, 50.0));
            println!(
                "  -> freshness lag p50 (insert -> searchable): {:.2} ms",
                percentile(&lags_ms, 50.0)
            );
        }
        println!("  ({} background re-freezes over the drill)", cluster.total_refreezes());
        cluster.shutdown();
    }

    // --- merge / coordinator path -------------------------------------------
    if run("merge") {
        let partials: Vec<Neighbor> =
            (0..100u32).map(|i| Neighbor::new(i % 60, 1.0 - (i as f32) * 0.01)).collect();
        bench(&mut rec, "coordinator/merge_topk 100 -> 10", &mut || {
            std::hint::black_box(merge_topk(std::hint::black_box(partials.clone()), 10));
            1
        });
    }

    // --- broker round trip ---------------------------------------------------
    if run("broker") {
        let b: Broker<u64> = Broker::new(BrokerConfig {
            rebalance_pause: Duration::from_millis(0),
            ..BrokerConfig::default()
        });
        b.create_topic("t");
        let c = b.subscribe("t", "g", 1).unwrap();
        let mut k = 0u64;
        bench(&mut rec, "broker/publish+poll+ack roundtrip", &mut || {
            b.publish("t", k, k).unwrap();
            let d = c.poll(Duration::from_millis(100)).unwrap();
            c.ack(&d);
            k += 1;
            1
        });
    }

    // --- rerank: native vs PJRT ----------------------------------------------
    if run("rerank") {
        let cands = SyntheticSpec::deep_like(512, 96, 5).generate();
        let q = SyntheticSpec::deep_like(512, 96, 5).queries(1);
        let ids: Vec<u32> = (0..cands.len() as u32).collect();
        bench(&mut rec, "rerank/native 512 cands d=96", &mut || {
            std::hint::black_box(
                NativeScorer.rerank(Metric::L2, q.get(0), cands.raw(), &ids, 10).unwrap(),
            );
            1
        });
        match default_artifacts_dir().map(PjrtScorer::spawn) {
            Some(Ok(pjrt)) => {
                bench(&mut rec, "rerank/pjrt 512 cands d=96 (AOT Pallas)", &mut || {
                    std::hint::black_box(
                        pjrt.rerank(Metric::L2, q.get(0), cands.raw(), &ids, 10).unwrap(),
                    );
                    1
                });
                bench(&mut rec, "scores/pjrt block 128x4096 d=96", &mut || {
                    let qb = SyntheticSpec::deep_like(128, 96, 9).generate();
                    let xb = SyntheticSpec::deep_like(4096, 96, 10).generate();
                    std::hint::black_box(
                        pjrt.scores(Metric::L2, qb.raw(), 128, xb.raw(), 4096, 96).unwrap(),
                    );
                    128 * 4096
                });
            }
            Some(Err(e)) => println!("rerank/pjrt: SKIP ({e})"),
            None => println!("rerank/pjrt: SKIP (run `make artifacts`)"),
        }
    }

    // --- chaos: seeded schedules against a live cluster ---------------------
    // A handful of smoke schedules through the chaos runner (ISSUE 6).
    // `chaos/violations` must stay 0 — it is a correctness canary riding
    // the bench trend, not a timing. `chaos/recovery-p99 ms` tracks the
    // heal -> full-coverage latency across the schedules (the full
    // distribution comes from the 500-schedule nightly sweep).
    if run("chaos") {
        use pyramid::chaos::runner::{harness_index, run_schedule_on, HARNESS_INDEX_SEED};
        use pyramid::chaos::schedule::ChaosSpec;
        let idx = harness_index(HARNESS_INDEX_SEED).expect("chaos harness index");
        let count = if smoke { 2 } else { 4 };
        let mut violations = 0usize;
        let mut recovery = Vec::new();
        for seed in 0..count as u64 {
            let spec = ChaosSpec { steps: 6, step_ms: 10, ..ChaosSpec::for_seed(0xBEEF + seed) };
            let report = run_schedule_on(&idx, &spec).expect("chaos schedule run");
            violations += report.violations.len();
            recovery.push(report.recovery_ms as f64);
            for v in &report.violations {
                println!("  CHAOS VIOLATION (seed {}): {v}", spec.seed);
            }
        }
        rec.record("chaos/violations", violations as f64);
        rec.record("chaos/recovery-p99 ms", percentile(&recovery, 99.0));
        println!(
            "chaos drill: {count} schedules, {violations} violations, \
             recovery p99 {:.0} ms",
            percentile(&recovery, 99.0)
        );
    }

    // --- load: trace replay + closed-loop elasticity (ISSUE 7) --------------
    // One hot-partition trace replayed twice against a throttled home host:
    // static placement vs the elasticity controller. Wall-clock report
    // numbers, not ns/op — the trend step watches the ratio.
    if run("load") {
        use pyramid::chaos::runner::{harness_index, HARNESS_INDEX_SEED};
        use pyramid::load::{run_trace, Arrival, ControllerConfig, LoadConfig, TraceSpec};
        let idx = harness_index(HARNESS_INDEX_SEED).expect("load harness index");
        let mut spec = TraceSpec::for_seed(7);
        spec.duration_ms = if smoke { 600 } else { 1_500 };
        spec.rate = if smoke { 200.0 } else { 400.0 };
        spec.arrival = Arrival::Poisson;
        spec.hot_partition = 2;
        spec.hot_frac = 0.9;
        let drill = |controller: Option<ControllerConfig>| {
            let topo = ClusterTopology {
                workers: 4,
                replicas: 1,
                coordinators: 2,
                net_latency_us: 1_000,
                rebalance_ms: 50,
                executor_batch: 4,
                ..ClusterTopology::default()
            };
            let coord_cfg = CoordinatorConfig {
                timeout: Duration::from_secs(10),
                hedge: HedgeConfig::disabled(),
                ..CoordinatorConfig::default()
            };
            let cluster =
                SimCluster::start_with(&idx, topo, None, coord_cfg).expect("start load cluster");
            cluster.set_cpu_share(2, 5);
            let cfg = LoadConfig {
                clients: 24,
                tick_ms: 20,
                params: QueryParams { k: 10, branch: 1, ef: 64, meta_ef: 64 },
                controller,
            };
            let report = run_trace(&cluster, &idx, &spec, &cfg).expect("load drill run");
            cluster.shutdown();
            report
        };
        let static_run = drill(None);
        let elastic = drill(Some(ControllerConfig {
            high_depth: 4.0,
            high_ticks: 2,
            cooldown_ticks: 5,
            max_replicas: 3,
            ..ControllerConfig::default()
        }));
        let ratio = static_run.hot_p99_us / elastic.hot_p99_us.max(1.0);
        rec.record("load/p99-static-vs-elastic", ratio);
        rec.record(
            "load/controller-reaction-ms",
            elastic.reaction_ms.unwrap_or(-1.0),
        );
        rec.record(
            "load/hot-partition-qps",
            elastic.hot_queries as f64 / (elastic.wall_ms / 1e3).max(1e-9),
        );
        println!(
            "load drill: hot p99 static {:.0} us vs elastic {:.0} us ({ratio:.2}x), \
             reaction {:.0} ms, {} scale-up(s)",
            static_run.hot_p99_us,
            elastic.hot_p99_us,
            elastic.reaction_ms.unwrap_or(-1.0),
            elastic.scale_ups
        );
    }

    // --- net: transport plane (ISSUE 8) -------------------------------------
    // The fat-tree fabric priced onto the broker seams. Report numbers
    // for the trend step: rack-local vs cross-rack gather p99 on
    // otherwise-identical clusters (the locality premium), and the hedge
    // win when each partition's two replicas sit at asymmetric distances
    // from the fabric root.
    if run("net") {
        use pyramid::net::NetSpec;
        let n = if smoke { 2_000 } else { 4_000 };
        let data = SyntheticSpec::deep_like(n, 16, 29).generate();
        let queries = SyntheticSpec::deep_like(n, 16, 29).queries(32);
        let cfg =
            IndexConfig { sample: n / 4, meta_size: 32, partitions: 4, ..IndexConfig::default() };
        let idx = PyramidIndex::build(&data, Metric::L2, &cfg).expect("build net bench index");
        let params = QueryParams { k: 10, branch: 4, ef: 100, meta_ef: 100 };
        let rounds = if smoke { 1 } else { 2 };
        let fat = NetSpec::FatTree { hop_us: 1_500, gbps: 10, oversub: 4 };
        let gather_p99 = |hosts_per_rack: usize, replicas: usize, hedge: HedgeConfig| {
            let topo = ClusterTopology {
                workers: 4,
                replicas,
                coordinators: 2,
                net_latency_us: 0,
                rebalance_ms: 100,
                executor_batch: 8,
                hosts_per_rack,
                net: fat,
                ..ClusterTopology::default()
            };
            let coord_cfg = CoordinatorConfig { hedge, ..CoordinatorConfig::default() };
            let cluster =
                SimCluster::start_with(&idx, topo, None, coord_cfg).expect("start net cluster");
            // Warm-up settles assignments and arms the hedge window on the
            // fabric's real latencies.
            for qi in 0..queries.len() {
                let _ = cluster.execute(queries.get(qi), &params);
            }
            let mut ms = Vec::new();
            for _ in 0..rounds {
                for qi in 0..queries.len() {
                    let t0 = Instant::now();
                    let _ = cluster.execute(queries.get(qi), &params);
                    ms.push(t0.elapsed().as_secs_f64() * 1e3);
                }
            }
            let hedges: u64 = cluster
                .coordinators()
                .iter()
                .map(|c| c.metrics.hedges_fired.load(std::sync::atomic::Ordering::Relaxed))
                .sum();
            cluster.shutdown();
            (percentile(&ms, 99.0), hedges)
        };
        let (local, _) = gather_p99(0, 1, HedgeConfig::disabled());
        let (cross, _) = gather_p99(1, 1, HedgeConfig::disabled());
        rec.record("net/same-rack-gather-p99 ms", local);
        rec.record("net/cross-rack-gather-p99 ms", cross);
        println!("net fabric: gather p99 rack-local {local:.2} ms vs cross-rack {cross:.2} ms");
        // Hedge win under asymmetric replica distance: hosts_per_rack = 2
        // leaves one replica of each partition near the fabric root and
        // one across the spine; hedged re-dispatch should cut the tail.
        let (unhedged, _) = gather_p99(2, 2, HedgeConfig::disabled());
        let (hedged, fired) = gather_p99(2, 2, HedgeConfig::default());
        let win = unhedged / hedged.max(1e-9);
        rec.record("net/hedge-win-ratio", win);
        println!(
            "net fabric: asymmetric p99 unhedged {unhedged:.2} ms vs hedged {hedged:.2} ms \
             ({win:.2}x, {fired} hedges)"
        );
    }

    // --- obs: telemetry plane (ISSUE 9) --------------------------------------
    // Overhead-when-on, recorded in percentage points: identical cluster +
    // workload with the plane attached (`ObsSpec::On`) vs detached
    // (`ObsSpec::Off`), and the profiled vs unprofiled bottom-layer walk
    // on the same frozen graph. `obs/scrape-us` prices one
    // snapshot-consistent scrape of a populated registry — the cost a
    // monitoring poll imposes on the serving path's coherence lock.
    if run("obs") {
        use pyramid::obs::{MetricsRegistry, ObsSpec};
        let n = if smoke { 2_000 } else { 4_000 };
        let data = SyntheticSpec::deep_like(n, 16, 61).generate();
        let queries = SyntheticSpec::deep_like(n, 16, 61).queries(64);
        let cfg =
            IndexConfig { sample: n / 4, meta_size: 32, partitions: 4, ..IndexConfig::default() };
        let idx = PyramidIndex::build(&data, Metric::L2, &cfg).expect("build obs bench index");
        let params = QueryParams { k: 10, branch: 4, ef: 100, meta_ef: 100 };
        let rounds = if smoke { 2 } else { 4 };
        let measure = |obs: ObsSpec| -> f64 {
            let topo = ClusterTopology {
                workers: 4,
                replicas: 1,
                coordinators: 2,
                net_latency_us: 0,
                rebalance_ms: 100,
                executor_batch: 8,
                obs,
                ..ClusterTopology::default()
            };
            let coord_cfg = CoordinatorConfig {
                hedge: HedgeConfig::disabled(),
                ..CoordinatorConfig::default()
            };
            let cluster =
                SimCluster::start_with(&idx, topo, None, coord_cfg).expect("start obs cluster");
            for qi in 0..queries.len() {
                let _ = cluster.execute(queries.get(qi), &params);
            }
            let mut us = Vec::new();
            for _ in 0..rounds {
                for qi in 0..queries.len() {
                    let t0 = Instant::now();
                    let _ = cluster.execute(queries.get(qi), &params);
                    us.push(t0.elapsed().as_secs_f64() * 1e6);
                }
            }
            cluster.shutdown();
            percentile(&us, 50.0)
        };
        let detached = measure(ObsSpec::Off);
        let attached = measure(ObsSpec::On);
        let trace_pct = (attached - detached) / detached.max(1e-9) * 100.0;
        rec.record("obs/trace-overhead-pct", trace_pct);
        println!(
            "obs drill: query p50 detached {detached:.0} us vs attached {attached:.0} us \
             ({trace_pct:+.2}%)"
        );

        // Scrape cost on a registry shaped like a live cluster's: a few
        // labelled counter series plus a populated latency histogram.
        let reg = MetricsRegistry::new();
        for p in 0..16u32 {
            reg.counter(&format!("bench_partials{{partition=\"{p}\"}}")).add(u64::from(p) + 1);
        }
        let h = reg.histogram("bench_latency_us");
        for i in 0..4096 {
            h.observe(100.0 + (i % 997) as f64);
        }
        let scrape_ns = bench(&mut rec, "obs/scrape 17 series", &mut || {
            std::hint::black_box(reg.scrape());
            1
        });
        rec.record("obs/scrape-us", scrape_ns / 1e3);

        // Walk hooks: the ProfileProbe instantiation vs the NoProbe one,
        // identical batch on the same frozen graph (results are pinned
        // bit-identical by the hnsw tests; this records the price).
        let wn = if smoke { 10_000 } else { 50_000 };
        let wdata = SyntheticSpec::deep_like(wn, 96, 3).generate();
        let wqueries = SyntheticSpec::deep_like(wn, 96, 3).queries(256);
        let wh = Hnsw::build(wdata, Metric::L2, HnswParams::default()).unwrap();
        let mut qi = 0usize;
        let plain_ns = bench(&mut rec, &format!("hnsw/walk-unprofiled n={wn} ef=100"), &mut || {
            let batch: Vec<BatchQuery<'_>> = (0..8)
                .map(|j| BatchQuery {
                    query: wqueries.get((qi + j) % wqueries.len()),
                    k: 10,
                    ef: 100,
                })
                .collect();
            std::hint::black_box(wh.search_batch(&batch, &NativeScorer));
            qi += 8;
            8
        });
        let mut qj = 0usize;
        let prof_ns = bench(&mut rec, &format!("hnsw/walk-profiled n={wn} ef=100"), &mut || {
            let batch: Vec<BatchQuery<'_>> = (0..8)
                .map(|j| BatchQuery {
                    query: wqueries.get((qj + j) % wqueries.len()),
                    k: 10,
                    ef: 100,
                })
                .collect();
            std::hint::black_box(wh.search_batch_profiled(&batch, &NativeScorer));
            qj += 8;
            8
        });
        let walk_pct = (prof_ns - plain_ns) / plain_ns.max(1e-9) * 100.0;
        rec.record("obs/walk-hook-overhead-pct", walk_pct);
        println!("  -> walk-hook overhead vs unprofiled walk: {walk_pct:+.2}%");
    }

    // --- repart: self-healing partition plane (ISSUE 10) ---------------------
    // One drift-triggered migration on a writable cluster. Two report
    // numbers for the trend step: the query p99 observed while the
    // migration ladder runs (live migration must never pause serving —
    // source and destination dual-serve until the epoch bump), and the
    // recall gap between the migrated layout and a from-scratch rebuild
    // over the identical rows.
    if run("repart") {
        use pyramid::config::RepartConfig;
        let n = if smoke { 2_000 } else { 4_000 };
        let dspec = SyntheticSpec::deep_like(n, 16, 83);
        let data = dspec.generate();
        let queries = dspec.queries(48);
        let cfg =
            IndexConfig { sample: n / 4, meta_size: 32, partitions: 4, ..IndexConfig::default() };
        let idx = PyramidIndex::build(&data, Metric::L2, &cfg).expect("build repart bench index");
        let topo = ClusterTopology {
            workers: 4,
            replicas: 1,
            coordinators: 2,
            net_latency_us: 0,
            rebalance_ms: 100,
            executor_batch: 8,
            ..ClusterTopology::default()
        };
        let cluster = SimCluster::start_ingesting(
            &idx,
            topo,
            IngestConfig::default(),
            CoordinatorConfig::default(),
        )
        .expect("start repart bench cluster");
        cluster
            .enable_repartition(RepartConfig { min_moves: 16, ..RepartConfig::default() })
            .expect("enable repartition");
        let params = QueryParams { k: 10, branch: 2, ef: 100, meta_ef: 100 };
        // Drift: an off-center shelf the frozen layout never saw, landing
        // unevenly across partitions so the planner has real moves.
        let extra_n = if smoke { 300 } else { 800 };
        let extra = SyntheticSpec::deep_like(extra_n, 16, 84).generate();
        let mut combined: Vec<f32> = Vec::with_capacity((n + extra_n) * 16);
        combined.extend_from_slice(data.raw());
        let mut ids: Vec<u32> = (0..n as u32).collect();
        for i in 0..extra_n {
            let v: Vec<f32> = extra.get(i).iter().map(|x| x + 2.0).collect();
            ids.push(cluster.insert(&v).expect("repart bench insert"));
            combined.extend_from_slice(&v);
        }
        assert!(
            cluster.wait_ingest_idle(Duration::from_secs(60)),
            "repart bench: replicas never drained the update log"
        );
        // Warm the read path before the drill.
        for qi in 0..queries.len() {
            let _ = cluster.execute(queries.get(qi), &params);
        }
        // Queries race the live migration on another thread; the floor of
        // 16 samples keeps the percentile meaningful when the ladder
        // finishes before the prober gets going.
        let mut pause_ms = Vec::new();
        let mut migrated = false;
        std::thread::scope(|s| {
            let h = s.spawn(|| cluster.trigger_repartition());
            while !h.is_finished() || pause_ms.len() < 16 {
                let t0 = Instant::now();
                let _ = cluster.execute(queries.get(pause_ms.len() % queries.len()), &params);
                pause_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            migrated = h.join().expect("migration thread").expect("trigger repartition");
        });
        assert!(migrated, "repart bench: planner produced no moves");
        assert!(
            cluster.wait_ingest_idle(Duration::from_secs(60)),
            "repart bench: retire stream never drained"
        );
        rec.record("repart/migration-pause-p99 ms", percentile(&pause_ms, 99.0));
        println!(
            "repart drill: {} queries raced the migration, p50 {:.2} ms / p99 {:.2} ms \
             ({} row(s) moved)",
            pause_ms.len(),
            percentile(&pause_ms, 50.0),
            percentile(&pause_ms, 99.0),
            cluster.repart_rows_moved()
        );

        // Recall parity against a from-scratch rebuild over the same
        // rows, at branch=2 of 4 so routing quality decides the number
        // (full fanout would hide a bad migrated layout).
        let all = pyramid::dataset::Dataset::from_vec(combined, 16).expect("combined dataset");
        let rebuild = PyramidIndex::build(&all, Metric::L2, &cfg).expect("rebuild repart index");
        let mut hits_cluster = 0usize;
        let mut hits_rebuild = 0usize;
        for qi in 0..queries.len() {
            let q = queries.get(qi);
            let gt: Vec<u32> = pyramid::bruteforce::search(&all, q, Metric::L2, 10)
                .iter()
                .map(|nb| nb.id)
                .collect();
            let gt_cluster: Vec<u32> = gt.iter().map(|&row| ids[row as usize]).collect();
            hits_cluster += cluster
                .execute(q, &params)
                .expect("cluster query")
                .iter()
                .filter(|nb| gt_cluster.contains(&nb.id))
                .count();
            hits_rebuild +=
                rebuild.search(q, &params).iter().filter(|nb| gt.contains(&nb.id)).count();
        }
        let total = (queries.len() * 10) as f64;
        let (r_cluster, r_rebuild) = (hits_cluster as f64 / total, hits_rebuild as f64 / total);
        rec.record("repart/recall-delta", r_rebuild - r_cluster);
        println!(
            "  -> post-migration recall@10 {r_cluster:.3} vs rebuild {r_rebuild:.3} \
             (delta {:+.3})",
            r_rebuild - r_cluster
        );
        cluster.shutdown();
    }

    if emit_json {
        let path = std::path::Path::new("BENCH_hot_paths.json");
        rec.write_json(path).expect("write bench json");
        println!("wrote {} measurements to {}", rec.len(), path.display());
    }
    println!("done.");
}
